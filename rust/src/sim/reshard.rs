//! Activation resharding strategies between consecutive pipeline stages
//! (§5, Figure 10).
//!
//! At a stage boundary the activation tensor `[micro_tokens, hidden]` must
//! move from the `tp_src` chips of stage *i* to the `tp_dst` chips of stage
//! *i+1*, which may be a different chip type with different NIC topology.
//!
//! * `NaiveP2p` — every destination chip pulls the full activation from one
//!   source chip: `tp_dst` full-size cross-node flows through one NIC.
//! * `Broadcast` — prior work [42]: one full-size cross-node transfer, then
//!   an intra-node broadcast on the destination server.
//! * `SendRecvAllGather` — the paper's topology-aware strategy: the tensor
//!   is split into `k = min(tp_src, tp_dst)` slices sent concurrently over
//!   *affine* NICs, then re-assembled with an intra-node all-gather.

use crate::comm::{cross_node_time, CommMode};
use crate::hetero::ChipSpec;
use crate::topology::NicAssignment;

/// Resharding strategy at pipeline-stage boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReshardStrategy {
    /// Naive sequential P2P between mismatched TP groups.
    NaiveP2p,
    /// Root-gather + tree broadcast to the destination group.
    Broadcast,
    /// The paper's SR&AG: sliced send/recv then all-gather (§4.2).
    SendRecvAllGather,
}

impl ReshardStrategy {
    /// Canonical token (`naive`, `bcast`, `srag`).
    pub fn name(self) -> &'static str {
        match self {
            ReshardStrategy::NaiveP2p => "naive P2P",
            ReshardStrategy::Broadcast => "broadcast",
            ReshardStrategy::SendRecvAllGather => "SR&AG (topology-aware)",
        }
    }

    /// Parse a canonical token.
    pub fn parse(s: &str) -> Option<ReshardStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "naive" | "naive-p2p" => Some(ReshardStrategy::NaiveP2p),
            "bcast" | "broadcast" => Some(ReshardStrategy::Broadcast),
            "srag" | "sr-ag" | "sendrecv-allgather" => Some(ReshardStrategy::SendRecvAllGather),
            _ => None,
        }
    }

    /// Canonical short token, accepted back by [`ReshardStrategy::parse`].
    pub fn token(self) -> &'static str {
        match self {
            ReshardStrategy::NaiveP2p => "naive",
            ReshardStrategy::Broadcast => "bcast",
            ReshardStrategy::SendRecvAllGather => "srag",
        }
    }
}

/// Cost of one resharding step: total wire time plus the slice of it the
/// §5 fine-grained overlap machinery can hide under compute (the single
/// streamed base transfer; the extra naive-P2P copies and the intra-node
/// collective tail are bursty and stay exposed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReshardCost {
    /// Total reshard seconds for one hop.
    pub total: f64,
    /// Portion of the total hideable under compute by fine-grained overlap.
    pub overlappable: f64,
}

/// Time (s) to reshard `bytes` of activation from a `tp_src`-way stage on
/// `src` chips to a `tp_dst`-way stage on `dst` chips.
#[allow(clippy::too_many_arguments)]
pub fn reshard_time(
    strategy: ReshardStrategy,
    mode: CommMode,
    bytes: usize,
    src: &ChipSpec,
    tp_src: usize,
    dst: &ChipSpec,
    tp_dst: usize,
    assign: NicAssignment,
) -> f64 {
    reshard_cost(strategy, mode, bytes, src, tp_src, dst, tp_dst, assign).total
}

/// Full cost decomposition (total + overlappable portion).
#[allow(clippy::too_many_arguments)]
pub fn reshard_cost(
    strategy: ReshardStrategy,
    mode: CommMode,
    bytes: usize,
    src: &ChipSpec,
    tp_src: usize,
    dst: &ChipSpec,
    tp_dst: usize,
    assign: NicAssignment,
) -> ReshardCost {
    let intra_bw = dst.intra_node.bandwidth_gbps(0, 1.min(dst.chips_per_node - 1)) * 1e9;
    match strategy {
        ReshardStrategy::NaiveP2p => {
            // tp_dst full-size flows contend for the same source NIC path;
            // only the first streamed copy can hide under compute.
            let one = cross_node_time(mode, bytes, src, dst, assign);
            ReshardCost { total: one * tp_dst as f64, overlappable: one }
        }
        ReshardStrategy::Broadcast => {
            // One full copy across nodes, then a tree broadcast inside the
            // destination server (the intra-node tail stays exposed).
            let cross = cross_node_time(mode, bytes, src, dst, assign);
            let fanout = (tp_dst as f64).log2().ceil().max(0.0);
            ReshardCost {
                total: cross + fanout * (bytes as f64 / intra_bw + 1e-6),
                overlappable: cross,
            }
        }
        ReshardStrategy::SendRecvAllGather => {
            // k concurrent slice transfers on affine NICs + intra-node
            // all-gather of the slices ((k-1)/k of the tensor per chip).
            let k = tp_src.min(tp_dst).max(1);
            let slice = bytes.div_ceil(k);
            let cross = cross_node_time(mode, slice, src, dst, assign);
            let ag = (k as f64 - 1.0) / k as f64 * bytes as f64 / intra_bw + 1e-6;
            ReshardCost { total: cross + ag, overlappable: cross }
        }
    }
}

/// How much of the overlappable slice the §5 machinery actually hides for
/// a given strategy: DDR reaches "near-lossless"; CPU-mediated RDMA hides
/// partially (staging blocks the copy engine); CPU-mediated TCP cannot
/// overlap at all (the host stack serializes with the device).
pub fn overlap_effectiveness(mode: CommMode) -> f64 {
    match mode {
        CommMode::DeviceDirect => 0.95,
        CommMode::RdmaCpu => 0.30,
        CommMode::TcpCpu => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{spec, ChipKind};

    const MB64: usize = 64 << 20;

    #[test]
    fn srag_beats_naive_and_broadcast() {
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let t_naive = reshard_time(ReshardStrategy::NaiveP2p, CommMode::DeviceDirect,
                                   MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        let t_bcast = reshard_time(ReshardStrategy::Broadcast, CommMode::DeviceDirect,
                                   MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        let t_srag = reshard_time(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                                  MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        assert!(t_srag < t_bcast, "srag {t_srag} vs bcast {t_bcast}");
        assert!(t_bcast < t_naive, "bcast {t_bcast} vs naive {t_naive}");
    }

    #[test]
    fn srag_scales_with_min_tp() {
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let t42 = reshard_time(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                               MB64, &a, 4, &b, 2, NicAssignment::Affinity);
        let t44 = reshard_time(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                               MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        assert!(t44 < t42); // more parallel slices
    }

    #[test]
    fn tcp_slower_than_ddr_for_all_strategies() {
        let a = spec(ChipKind::A);
        let c = spec(ChipKind::C);
        for s in [ReshardStrategy::NaiveP2p, ReshardStrategy::Broadcast,
                  ReshardStrategy::SendRecvAllGather] {
            let ddr = reshard_time(s, CommMode::DeviceDirect, MB64, &a, 4, &c, 4,
                                   NicAssignment::Affinity);
            let tcp = reshard_time(s, CommMode::TcpCpu, MB64, &a, 4, &c, 4,
                                   NicAssignment::Affinity);
            assert!(tcp > 2.0 * ddr, "{}: tcp {tcp} ddr {ddr}", s.name());
        }
    }

    const ALL_STRATEGIES: [ReshardStrategy; 3] = [
        ReshardStrategy::NaiveP2p,
        ReshardStrategy::Broadcast,
        ReshardStrategy::SendRecvAllGather,
    ];

    #[test]
    fn naive_pays_one_full_copy_per_destination_chip() {
        // The sizing law of the naive path: total = tp_dst serialized
        // copies of the full tensor, of which exactly one (the streamed
        // first copy) is overlappable — bitwise, not approximately.
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        for tp_dst in [1usize, 2, 4, 8] {
            let c = reshard_cost(ReshardStrategy::NaiveP2p, CommMode::DeviceDirect,
                                 MB64, &a, 4, &b, tp_dst, NicAssignment::Affinity);
            assert_eq!(c.total, c.overlappable * tp_dst as f64, "tp_dst {tp_dst}");
        }
    }

    #[test]
    fn broadcast_to_one_chip_degenerates_to_a_single_cross_copy() {
        // tp_dst = 1 means no intra-node fan-out: the whole cost is the one
        // cross-node transfer and all of it is overlappable.
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let c = reshard_cost(ReshardStrategy::Broadcast, CommMode::DeviceDirect,
                             MB64, &a, 4, &b, 1, NicAssignment::Affinity);
        assert_eq!(c.total, c.overlappable);
    }

    #[test]
    fn srag_slices_by_the_smaller_tp_degree() {
        // k = min(tp_src, tp_dst): widening the destination beyond the
        // source changes nothing (bitwise), because the source can only
        // cut the tensor into tp_src affine slices.
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let at4 = reshard_cost(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                               MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        let at8 = reshard_cost(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                               MB64, &a, 4, &b, 8, NicAssignment::Affinity);
        assert_eq!(at4, at8);
    }

    #[test]
    fn srag_cost_decomposes_into_slice_transfer_plus_all_gather() {
        // The documented sizing: one cross-node transfer of a
        // ceil(bytes / k) slice, plus an intra-node all-gather of the
        // remaining (k-1)/k of the tensor. Pin the decomposition bitwise
        // against the public comm primitives it is built from.
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let (tp_src, tp_dst) = (4usize, 2usize);
        let k = tp_src.min(tp_dst);
        let c = reshard_cost(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                             MB64, &a, tp_src, &b, tp_dst, NicAssignment::Affinity);
        let slice = MB64.div_ceil(k);
        let cross = cross_node_time(CommMode::DeviceDirect, slice, &a, &b,
                                    NicAssignment::Affinity);
        let intra_bw = b.intra_node.bandwidth_gbps(0, 1) * 1e9;
        let ag = (k as f64 - 1.0) / k as f64 * MB64 as f64 / intra_bw + 1e-6;
        assert_eq!(c.overlappable, cross);
        assert_eq!(c.total, cross + ag);
    }

    #[test]
    fn cost_grows_with_bytes_for_every_strategy() {
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        for s in ALL_STRATEGIES {
            let small = reshard_time(s, CommMode::DeviceDirect, MB64, &a, 4, &b, 4,
                                     NicAssignment::Affinity);
            let large = reshard_time(s, CommMode::DeviceDirect, 4 * MB64, &a, 4, &b, 4,
                                     NicAssignment::Affinity);
            assert!(large > small, "{}: {large} !> {small}", s.name());
        }
    }

    #[test]
    fn reshard_cost_is_invariant_under_dp_replica_permutation() {
        // Every DP replica of a stage pair prices the same hop: the cost is
        // a pure function of (strategy, mode, bytes, specs, tps), with no
        // hidden per-call or replica-order state. Price a batch of replica
        // hops in natural order and again in a shuffled order — every
        // replica's cost must be bitwise identical, which is exactly the
        // property that lets the simulator charge one link cost per stage
        // boundary instead of one per DP replica.
        use crate::util::prop;
        prop::check(40, |rng| {
            let kinds = [ChipKind::A, ChipKind::B, ChipKind::C];
            let src = spec(*rng.choose(&kinds));
            let dst = spec(*rng.choose(&kinds));
            let strategy = *rng.choose(&ALL_STRATEGIES);
            let mode = *rng.choose(&[CommMode::TcpCpu, CommMode::RdmaCpu,
                                     CommMode::DeviceDirect]);
            let assign = *rng.choose(&[NicAssignment::Affinity,
                                       NicAssignment::NonAffinity]);
            let bytes = rng.usize(1, 1 << 28);
            let tp_src = *rng.choose(&[1usize, 2, 4, 8]);
            let tp_dst = *rng.choose(&[1usize, 2, 4, 8]);
            let replicas = rng.usize(2, 9);
            let natural: Vec<ReshardCost> = (0..replicas)
                .map(|_| reshard_cost(strategy, mode, bytes, &src, tp_src, &dst,
                                      tp_dst, assign))
                .collect();
            let mut order: Vec<usize> = (0..replicas).collect();
            rng.shuffle(&mut order);
            for &r in &order {
                let again = reshard_cost(strategy, mode, bytes, &src, tp_src, &dst,
                                         tp_dst, assign);
                prop::assert_prop(again == natural[r],
                                  format!("replica {r} drifted: {again:?} vs {:?}",
                                          natural[r]))?;
            }
            Ok(())
        });
    }
}
