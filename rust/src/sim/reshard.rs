//! Activation resharding strategies between consecutive pipeline stages
//! (§5, Figure 10).
//!
//! At a stage boundary the activation tensor `[micro_tokens, hidden]` must
//! move from the `tp_src` chips of stage *i* to the `tp_dst` chips of stage
//! *i+1*, which may be a different chip type with different NIC topology.
//!
//! * `NaiveP2p` — every destination chip pulls the full activation from one
//!   source chip: `tp_dst` full-size cross-node flows through one NIC.
//! * `Broadcast` — prior work [42]: one full-size cross-node transfer, then
//!   an intra-node broadcast on the destination server.
//! * `SendRecvAllGather` — the paper's topology-aware strategy: the tensor
//!   is split into `k = min(tp_src, tp_dst)` slices sent concurrently over
//!   *affine* NICs, then re-assembled with an intra-node all-gather.

use crate::comm::{cross_node_time, CommMode};
use crate::hetero::ChipSpec;
use crate::topology::NicAssignment;

/// Resharding strategy at pipeline-stage boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReshardStrategy {
    /// Naive sequential P2P between mismatched TP groups.
    NaiveP2p,
    /// Root-gather + tree broadcast to the destination group.
    Broadcast,
    /// The paper's SR&AG: sliced send/recv then all-gather (§4.2).
    SendRecvAllGather,
}

impl ReshardStrategy {
    /// Canonical token (`naive`, `bcast`, `srag`).
    pub fn name(self) -> &'static str {
        match self {
            ReshardStrategy::NaiveP2p => "naive P2P",
            ReshardStrategy::Broadcast => "broadcast",
            ReshardStrategy::SendRecvAllGather => "SR&AG (topology-aware)",
        }
    }

    /// Parse a canonical token.
    pub fn parse(s: &str) -> Option<ReshardStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "naive" | "naive-p2p" => Some(ReshardStrategy::NaiveP2p),
            "bcast" | "broadcast" => Some(ReshardStrategy::Broadcast),
            "srag" | "sr-ag" | "sendrecv-allgather" => Some(ReshardStrategy::SendRecvAllGather),
            _ => None,
        }
    }

    /// Canonical short token, accepted back by [`ReshardStrategy::parse`].
    pub fn token(self) -> &'static str {
        match self {
            ReshardStrategy::NaiveP2p => "naive",
            ReshardStrategy::Broadcast => "bcast",
            ReshardStrategy::SendRecvAllGather => "srag",
        }
    }
}

/// Cost of one resharding step: total wire time plus the slice of it the
/// §5 fine-grained overlap machinery can hide under compute (the single
/// streamed base transfer; the extra naive-P2P copies and the intra-node
/// collective tail are bursty and stay exposed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReshardCost {
    /// Total reshard seconds for one hop.
    pub total: f64,
    /// Portion of the total hideable under compute by fine-grained overlap.
    pub overlappable: f64,
}

/// Time (s) to reshard `bytes` of activation from a `tp_src`-way stage on
/// `src` chips to a `tp_dst`-way stage on `dst` chips.
#[allow(clippy::too_many_arguments)]
pub fn reshard_time(
    strategy: ReshardStrategy,
    mode: CommMode,
    bytes: usize,
    src: &ChipSpec,
    tp_src: usize,
    dst: &ChipSpec,
    tp_dst: usize,
    assign: NicAssignment,
) -> f64 {
    reshard_cost(strategy, mode, bytes, src, tp_src, dst, tp_dst, assign).total
}

/// Full cost decomposition (total + overlappable portion).
#[allow(clippy::too_many_arguments)]
pub fn reshard_cost(
    strategy: ReshardStrategy,
    mode: CommMode,
    bytes: usize,
    src: &ChipSpec,
    tp_src: usize,
    dst: &ChipSpec,
    tp_dst: usize,
    assign: NicAssignment,
) -> ReshardCost {
    let intra_bw = dst.intra_node.bandwidth_gbps(0, 1.min(dst.chips_per_node - 1)) * 1e9;
    match strategy {
        ReshardStrategy::NaiveP2p => {
            // tp_dst full-size flows contend for the same source NIC path;
            // only the first streamed copy can hide under compute.
            let one = cross_node_time(mode, bytes, src, dst, assign);
            ReshardCost { total: one * tp_dst as f64, overlappable: one }
        }
        ReshardStrategy::Broadcast => {
            // One full copy across nodes, then a tree broadcast inside the
            // destination server (the intra-node tail stays exposed).
            let cross = cross_node_time(mode, bytes, src, dst, assign);
            let fanout = (tp_dst as f64).log2().ceil().max(0.0);
            ReshardCost {
                total: cross + fanout * (bytes as f64 / intra_bw + 1e-6),
                overlappable: cross,
            }
        }
        ReshardStrategy::SendRecvAllGather => {
            // k concurrent slice transfers on affine NICs + intra-node
            // all-gather of the slices ((k-1)/k of the tensor per chip).
            let k = tp_src.min(tp_dst).max(1);
            let slice = bytes.div_ceil(k);
            let cross = cross_node_time(mode, slice, src, dst, assign);
            let ag = (k as f64 - 1.0) / k as f64 * bytes as f64 / intra_bw + 1e-6;
            ReshardCost { total: cross + ag, overlappable: cross }
        }
    }
}

/// How much of the overlappable slice the §5 machinery actually hides for
/// a given strategy: DDR reaches "near-lossless"; CPU-mediated RDMA hides
/// partially (staging blocks the copy engine); CPU-mediated TCP cannot
/// overlap at all (the host stack serializes with the device).
pub fn overlap_effectiveness(mode: CommMode) -> f64 {
    match mode {
        CommMode::DeviceDirect => 0.95,
        CommMode::RdmaCpu => 0.30,
        CommMode::TcpCpu => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{spec, ChipKind};

    const MB64: usize = 64 << 20;

    #[test]
    fn srag_beats_naive_and_broadcast() {
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let t_naive = reshard_time(ReshardStrategy::NaiveP2p, CommMode::DeviceDirect,
                                   MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        let t_bcast = reshard_time(ReshardStrategy::Broadcast, CommMode::DeviceDirect,
                                   MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        let t_srag = reshard_time(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                                  MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        assert!(t_srag < t_bcast, "srag {t_srag} vs bcast {t_bcast}");
        assert!(t_bcast < t_naive, "bcast {t_bcast} vs naive {t_naive}");
    }

    #[test]
    fn srag_scales_with_min_tp() {
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let t42 = reshard_time(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                               MB64, &a, 4, &b, 2, NicAssignment::Affinity);
        let t44 = reshard_time(ReshardStrategy::SendRecvAllGather, CommMode::DeviceDirect,
                               MB64, &a, 4, &b, 4, NicAssignment::Affinity);
        assert!(t44 < t42); // more parallel slices
    }

    #[test]
    fn tcp_slower_than_ddr_for_all_strategies() {
        let a = spec(ChipKind::A);
        let c = spec(ChipKind::C);
        for s in [ReshardStrategy::NaiveP2p, ReshardStrategy::Broadcast,
                  ReshardStrategy::SendRecvAllGather] {
            let ddr = reshard_time(s, CommMode::DeviceDirect, MB64, &a, 4, &c, 4,
                                   NicAssignment::Affinity);
            let tcp = reshard_time(s, CommMode::TcpCpu, MB64, &a, 4, &c, 4,
                                   NicAssignment::Affinity);
            assert!(tcp > 2.0 * ddr, "{}: tcp {tcp} ddr {ddr}", s.name());
        }
    }
}
