//! Discrete-event execution of heterogeneous 1F1B pipelines (§4.2).
//!
//! Simulates every (micro-batch × stage) forward/backward op with true 1F1B
//! issue order per stage, inter-stage activation resharding from
//! [`super::reshard`], and optional fine-grained compute/communication
//! overlap (§5's four-phase decomposition, modeled as hiding a calibrated
//! fraction of the P2P time under compute).
//!
//! The simulator is the execution-level cross-check of the closed-form cost
//! model (§4.3.2): `tests::sim_close_to_cost_model` keeps them honest
//! against each other, and the Table 9 ablations are run here.

use crate::comm::CommMode;
use crate::costmodel::{profile_layer, ModelShape, Strategy};
use crate::hetero::ChipGroup;
use crate::topology::NicAssignment;

use super::reshard::{overlap_effectiveness, reshard_cost, ReshardStrategy};

/// Fraction of P2P transfer time hidden by the fine-grained overlap of §5
/// ("near-lossless": forward, backward-recompute, backward-input,
/// backward-weight phases interleaved with comm).
pub const FINE_OVERLAP_HIDDEN: f64 = 0.95;

/// Simulation options (the Table 9 ablation axes).
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub comm: CommMode,
    pub reshard: ReshardStrategy,
    pub nic_assignment: NicAssignment,
    /// Fine-grained P2P/compute overlap enabled.
    pub fine_overlap: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            comm: CommMode::DeviceDirect,
            reshard: ReshardStrategy::SendRecvAllGather,
            nic_assignment: NicAssignment::Affinity,
            fine_overlap: true,
        }
    }
}

/// One pipeline stage as the simulator sees it.
#[derive(Clone, Debug)]
struct StageSim {
    t_fwd: f64,
    t_bwd: f64,
    t_update: f64,
    group: usize,
    s_tp: usize,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub iteration_seconds: f64,
    /// Busy compute seconds per stage.
    pub busy: Vec<f64>,
    /// Bubble (idle) fraction of the critical stage.
    pub bubble_fraction: f64,
    /// Total exposed (non-overlapped) communication seconds on the
    /// critical path stage.
    pub exposed_comm: f64,
}

/// Build per-stage timings from a strategy and simulate one iteration.
pub fn simulate_iteration(
    model: &ModelShape,
    groups: &[&ChipGroup],
    strategy: &Strategy,
    micro_tokens: usize,
    opts: &SimOptions,
) -> SimResult {
    // Expand group plans into a flat stage list (HeteroPP stage order),
    // applying the same memory/offload decisions as the cost model.
    let total_stages: usize = strategy.plans.iter().map(|p| p.s_pp).sum();
    let mut stages = Vec::new();
    let mut first_stage = 0usize;
    for (gi, (g, plan)) in groups.iter().zip(&strategy.plans).enumerate() {
        let prof = profile_layer(&g.spec, model, plan.s_tp, micro_tokens, strategy.s_dp);
        let lps = plan.layers_per_stage() as f64;
        let recomp = if plan.recompute { prof.t_recompute } else { 0.0 };
        let mem = crate::costmodel::stage_memory_bytes(
            &g.spec, model, plan, strategy, first_stage, total_stages, micro_tokens,
            first_stage == 0, first_stage + plan.s_pp == total_stages,
        );
        // Offloaded groups pay the synchronous gradient-streaming stall per
        // microbatch (charged to backward) and PCIe traffic at update time.
        let (off_micro, off_iter) = if mem.offloaded {
            (lps * prof.t_offload_micro, lps * prof.t_offload)
        } else {
            (0.0, 0.0)
        };
        for _ in 0..plan.s_pp {
            stages.push(StageSim {
                t_fwd: lps * prof.t_fwd,
                t_bwd: lps * (prof.t_bwd + recomp) + off_micro,
                t_update: lps * prof.t_update + off_iter,
                group: gi,
                s_tp: plan.s_tp,
            });
        }
        first_stage += plan.s_pp;
    }
    let act_bytes = micro_tokens * model.hidden * 2; // bf16 activations

    // Inter-stage transfer times (forward direction; gradients are the same
    // size on the way back).
    // Pre-compute EXPOSED per-hop time: total reshard cost minus whatever
    // the fine-grained overlap machinery hides (mode-dependent, and only
    // the streamed base transfer is hideable).
    let eff = if opts.fine_overlap { overlap_effectiveness(opts.comm) } else { 0.0 };
    let mut link = vec![0.0f64; stages.len().saturating_sub(1)];
    for s in 0..link.len() {
        let src = &groups[stages[s].group].spec;
        let dst = &groups[stages[s + 1].group].spec;
        let cost = reshard_cost(
            opts.reshard, opts.comm, act_bytes,
            src, stages[s].s_tp, dst, stages[s + 1].s_tp,
            opts.nic_assignment,
        );
        link[s] = cost.total - eff * cost.overlappable;
    }
    let exposed = |t: f64| t;

    simulate_1f1b(&stages, &link, strategy.micro_batches, &exposed)
}

/// Simulate a serialized [`crate::plan::ExecutionPlan`] — the plan-centric
/// entry point; a free-function alias for
/// [`crate::plan::ExecutionPlan::simulate`].
pub fn simulate_plan(plan: &crate::plan::ExecutionPlan) -> SimResult {
    plan.simulate()
}

/// Core 1F1B list scheduler over explicit per-stage op queues.
fn simulate_1f1b(
    stages: &[StageSim],
    link: &[f64],
    micro_batches: usize,
    exposed: &dyn Fn(f64) -> f64,
) -> SimResult {
    let s_n = stages.len();
    let b = micro_batches;
    const UNSET: f64 = -1.0;
    // fwd_done[m][s], bwd_done[m][s]
    let mut fwd_done = vec![vec![UNSET; s_n]; b];
    let mut bwd_done = vec![vec![UNSET; s_n]; b];

    // Static 1F1B issue order per stage.
    #[derive(Clone, Copy, Debug)]
    enum Op {
        F(usize),
        B(usize),
    }
    let mut queues: Vec<Vec<Op>> = Vec::with_capacity(s_n);
    for s in 0..s_n {
        let warm = (s_n - s).min(b);
        let mut q = Vec::with_capacity(2 * b);
        for m in 0..warm {
            q.push(Op::F(m));
        }
        let mut next_f = warm;
        let mut next_b = 0;
        while next_f < b {
            q.push(Op::B(next_b));
            next_b += 1;
            q.push(Op::F(next_f));
            next_f += 1;
        }
        while next_b < b {
            q.push(Op::B(next_b));
            next_b += 1;
        }
        queues.push(q);
    }

    let mut head = vec![0usize; s_n]; // next op index per stage
    let mut clock = vec![0.0f64; s_n]; // stage-busy-until
    let mut busy = vec![0.0f64; s_n];
    let mut exposed_comm = vec![0.0f64; s_n];

    // Fixed-point scheduling: keep sweeping stages until no progress.
    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..s_n {
            while head[s] < queues[s].len() {
                let op = queues[s][head[s]];
                // Readiness: input availability time, or None if dep not done.
                let ready = match op {
                    Op::F(m) => {
                        if s == 0 {
                            Some(0.0)
                        } else if fwd_done[m][s - 1] >= 0.0 {
                            Some(fwd_done[m][s - 1] + exposed(link[s - 1]))
                        } else {
                            None
                        }
                    }
                    Op::B(m) => {
                        if fwd_done[m][s] < 0.0 {
                            None
                        } else if s == s_n - 1 {
                            Some(fwd_done[m][s])
                        } else if bwd_done[m][s + 1] >= 0.0 {
                            Some(bwd_done[m][s + 1] + exposed(link[s]))
                        } else {
                            None
                        }
                    }
                };
                let Some(ready) = ready else { break };
                let start = clock[s].max(ready);
                let (dur, m, is_f) = match op {
                    Op::F(m) => (stages[s].t_fwd, m, true),
                    Op::B(m) => (stages[s].t_bwd, m, false),
                };
                let wait_comm = (ready - clock[s]).max(0.0);
                exposed_comm[s] += wait_comm.min(match op {
                    Op::F(_) if s > 0 => exposed(link[s - 1]),
                    Op::B(_) if s < s_n - 1 => exposed(link[s]),
                    _ => 0.0,
                });
                let end = start + dur;
                clock[s] = end;
                busy[s] += dur;
                if is_f {
                    fwd_done[m][s] = end;
                } else {
                    bwd_done[m][s] = end;
                }
                head[s] += 1;
                progressed = true;
            }
        }
    }
    debug_assert!(head.iter().zip(&queues).all(|(h, q)| *h == q.len()),
                  "pipeline deadlocked");

    // Optimizer update (+ exposed DP sync) appended per stage.
    let mut iteration: f64 = 0.0;
    for s in 0..s_n {
        iteration = iteration.max(clock[s] + stages[s].t_update);
    }
    let crit = (0..s_n)
        .max_by(|&a, &b| {
            (clock[a] + stages[a].t_update)
                .partial_cmp(&(clock[b] + stages[b].t_update))
                .unwrap()
        })
        .unwrap();
    let bubble_fraction = 1.0 - busy[crit] / clock[crit];

    SimResult {
        iteration_seconds: iteration,
        busy,
        bubble_fraction,
        exposed_comm: exposed_comm[crit],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{evaluate, GroupPlan, H2_100B};
    use crate::hetero::{experiment, homogeneous_baseline, ChipKind};

    fn table6_a_strategy() -> Strategy {
        Strategy {
            s_dp: 4,
            micro_batches: 128,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
        }
    }

    #[test]
    fn sim_close_to_cost_model() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = table6_a_strategy();
        let sim = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        let cm = evaluate(&H2_100B, &groups, &strategy, 4096, 1.0);
        let rel = (sim.iteration_seconds - cm.iteration_seconds).abs() / cm.iteration_seconds;
        assert!(rel < 0.15, "sim {} vs cost model {}", sim.iteration_seconds,
                cm.iteration_seconds);
    }

    #[test]
    fn bubble_fraction_matches_1f1b_theory() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = table6_a_strategy();
        let sim = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        // 1F1B bubble ≈ (pp-1)/(b + pp - 1) = 15/143 ≈ 0.105.
        assert!((sim.bubble_fraction - 15.0 / 143.0).abs() < 0.03,
                "bubble {}", sim.bubble_fraction);
    }

    #[test]
    fn tcp_slower_than_ddr_end_to_end() {
        let exp = experiment("exp-a-1").unwrap();
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_dp: 4,
            micro_batches: 128,
            plans: vec![
                GroupPlan { s_pp: 16, s_tp: 4, layers: 40, recompute: false },
                GroupPlan { s_pp: 16, s_tp: 4, layers: 40, recompute: true },
                GroupPlan { s_pp: 16, s_tp: 4, layers: 16, recompute: true },
            ],
        };
        let ddr = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        let tcp = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions {
            comm: CommMode::TcpCpu,
            fine_overlap: false,
            ..Default::default()
        });
        assert!(tcp.iteration_seconds > ddr.iteration_seconds);
    }

    #[test]
    fn overlap_reduces_iteration_time() {
        let exp = experiment("exp-a-1").unwrap();
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_dp: 2,
            micro_batches: 256,
            plans: vec![
                GroupPlan { s_pp: 32, s_tp: 4, layers: 40, recompute: false },
                GroupPlan { s_pp: 32, s_tp: 4, layers: 40, recompute: true },
                GroupPlan { s_pp: 32, s_tp: 4, layers: 16, recompute: true },
            ],
        };
        let with = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        let without = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions {
            fine_overlap: false,
            ..Default::default()
        });
        assert!(without.iteration_seconds > with.iteration_seconds);
    }

    #[test]
    fn all_ops_complete() {
        let exp = homogeneous_baseline(ChipKind::B);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_dp: 8,
            micro_batches: 64,
            plans: vec![GroupPlan { s_pp: 8, s_tp: 4, layers: 96, recompute: true }],
        };
        let sim = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        assert!(sim.iteration_seconds.is_finite());
        assert!(sim.busy.iter().all(|&x| x > 0.0));
    }
}
