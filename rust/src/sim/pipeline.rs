//! Discrete-event execution of heterogeneous pipelines (§4.2).
//!
//! Simulates every (micro-batch × stage) forward/backward op with a real
//! issue order for each [`Schedule`] variant — classic 1F1B, interleaved
//! 1F1B over virtual stage chunks, and a zero-bubble schedule with the
//! backward pass split into input- and weight-gradient phases — plus
//! inter-stage activation resharding from [`super::reshard`] and optional
//! fine-grained compute/communication overlap (§5's four-phase
//! decomposition, modeled as hiding a calibrated fraction of the P2P time
//! under compute).
//!
//! The simulator is the execution-level cross-check of the closed-form cost
//! model (§4.3.2), which folds each schedule into a single bubble
//! coefficient: the parity tests here and in `tests/integration.rs` keep
//! the two honest against each other per schedule, and the Table 9
//! ablations are run here.
//!
//! Since the flat-arena refactor this module owns the *pricing* (per-stage
//! timing tables, reshard link costs) and the plan-level entry points; the
//! hot event loop lives in [`super::engine`] ([`SimEngine`]), and the
//! original executors survive verbatim in [`super::reference`] as the
//! differential-testing baseline.

use std::thread;

use anyhow::Result;

use crate::comm::CommMode;
use crate::costmodel::{profile_layer_comm, ModelShape, Strategy};
use crate::elastic::FaultPlan;
use crate::hetero::ChipGroup;
use crate::topology::NicAssignment;

use super::engine::{EventTimeline, SimEngine};
use super::reshard::{overlap_effectiveness, reshard_cost, ReshardStrategy};

/// Fraction of P2P transfer time hidden by the fine-grained overlap of §5
/// ("near-lossless": forward, backward-recompute, backward-input,
/// backward-weight phases interleaved with comm).
pub const FINE_OVERLAP_HIDDEN: f64 = 0.95;

/// Simulation options (the Table 9 ablation axes). The pipeline schedule
/// and the DP-collective algorithm are not options here — they travel
/// with the [`Strategy`](crate::costmodel::Strategy) so that search, cost
/// model and simulator always agree on them.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Cross-chip communication strategy (TCP / CPU-RDMA / device-direct).
    pub comm: CommMode,
    /// Inter-stage activation resharding strategy (§4.2).
    pub reshard: ReshardStrategy,
    /// NIC selection policy (§5 affinity model).
    pub nic_assignment: NicAssignment,
    /// Fine-grained P2P/compute overlap enabled.
    pub fine_overlap: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            comm: CommMode::DeviceDirect,
            reshard: ReshardStrategy::SendRecvAllGather,
            nic_assignment: NicAssignment::Affinity,
            fine_overlap: true,
        }
    }
}

/// One pipeline stage as the simulator sees it — also the timing table
/// the coordinator's plan-driven virtual evaluator executes against
/// ([`crate::coordinator::train_virtual`]), so both evaluators price a
/// stage identically.
#[derive(Clone, Debug)]
pub(crate) struct StageSim {
    pub(crate) t_fwd: f64,
    /// Full backward: input + weight gradients, recompute, offload stall.
    pub(crate) t_bwd: f64,
    /// Zero-bubble input-gradient phase (critical path; includes the
    /// activation recompute that must precede it).
    pub(crate) t_bwd_input: f64,
    /// Zero-bubble weight-gradient phase (bubble filler; includes the
    /// per-microbatch gradient-offload stall).
    pub(crate) t_bwd_weight: f64,
    pub(crate) t_update: f64,
    /// Exposed DP gradient-sync slice already included in `t_update`
    /// (the virtual coordinator replaces it with the executed
    /// collective's own accounting).
    pub(crate) t_update_comm: f64,
    /// Layers this stage holds (`layers_per_stage` of its group plan).
    pub(crate) lps: f64,
    /// Modeled bf16 gradient bytes of one layer on one chip (after TP
    /// sharding) — what the DP collective moves per layer.
    pub(crate) grad_bytes_per_layer: f64,
    pub(crate) group: usize,
    pub(crate) s_tp: usize,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Seconds for one full iteration (pipeline flush + optimizer update).
    pub iteration_seconds: f64,
    /// Busy compute seconds per stage.
    pub busy: Vec<f64>,
    /// Bubble (idle) fraction of the critical stage.
    pub bubble_fraction: f64,
    /// Total exposed (non-overlapped) communication seconds on the
    /// critical path stage.
    pub exposed_comm: f64,
}

/// Build per-stage timings from a strategy and simulate one iteration
/// under the strategy's [`Schedule`](crate::costmodel::Schedule).
///
/// One-shot convenience over [`SimEngine`]: hot callers that price the
/// same strategy repeatedly (the elastic loop, fleet sweeps, benches)
/// should build the engine once and call [`SimEngine::run`] per iteration.
pub fn simulate_iteration(
    model: &ModelShape,
    groups: &[&ChipGroup],
    strategy: &Strategy,
    micro_tokens: usize,
    opts: &SimOptions,
) -> SimResult {
    SimEngine::new(model, groups, strategy, micro_tokens, opts).run()
}

/// [`simulate_iteration`] plus the machine-readable [`EventTimeline`] —
/// the engine-path emitter the golden-snapshot harness pins.
pub fn simulate_iteration_timeline(
    model: &ModelShape,
    groups: &[&ChipGroup],
    strategy: &Strategy,
    micro_tokens: usize,
    opts: &SimOptions,
) -> (SimResult, EventTimeline) {
    SimEngine::new(model, groups, strategy, micro_tokens, opts).run_timeline()
}

/// Expand group plans into a flat per-stage timing table (HeteroPP stage
/// order), applying the same memory/offload decisions as the cost model.
/// Shared by [`simulate_iteration`] and the coordinator's plan-driven
/// virtual evaluator so the two execution-level views cannot diverge.
pub(crate) fn plan_stage_sims(
    model: &ModelShape,
    groups: &[&ChipGroup],
    strategy: &Strategy,
    micro_tokens: usize,
    opts: &SimOptions,
) -> Vec<StageSim> {
    let total_stages: usize = strategy.plans.iter().map(|p| p.s_pp).sum();
    let mut stages = Vec::new();
    let mut first_stage = 0usize;
    for (gi, (g, plan)) in groups.iter().zip(&strategy.plans).enumerate() {
        let prof = profile_layer_comm(
            &g.spec, model, plan.s_tp, micro_tokens, strategy.s_dp, strategy.s_ep,
            strategy.comm_algo, opts.nic_assignment,
        );
        let lps = plan.layers_per_stage() as f64;
        let recomp = if plan.recompute { prof.t_recompute } else { 0.0 };
        let mem = crate::costmodel::stage_memory_bytes(
            &g.spec, model, plan, strategy, first_stage, total_stages, micro_tokens,
            first_stage == 0, first_stage + plan.s_pp == total_stages,
        );
        // Offloaded groups pay the synchronous gradient-streaming stall per
        // microbatch (charged to backward) and PCIe traffic at update time.
        let (off_micro, off_iter) = if mem.offloaded {
            (lps * prof.t_offload_micro, lps * prof.t_offload)
        } else {
            (0.0, 0.0)
        };
        let t_bwd_base = lps * prof.t_bwd;
        for _ in 0..plan.s_pp {
            stages.push(StageSim {
                t_fwd: lps * prof.t_fwd,
                t_bwd: t_bwd_base + lps * recomp + off_micro,
                t_bwd_input: t_bwd_base / 2.0 + lps * recomp,
                t_bwd_weight: t_bwd_base / 2.0 + off_micro,
                t_update: lps * prof.t_update + off_iter,
                t_update_comm: lps * prof.t_dp_sync,
                lps,
                grad_bytes_per_layer: prof.params_per_chip * 2.0,
                group: gi,
                s_tp: plan.s_tp,
            });
        }
        first_stage += plan.s_pp;
    }
    stages
}

/// Inter-stage transfer times (forward direction; gradients are the same
/// size on the way back): EXPOSED per-hop time — total reshard cost minus
/// whatever the fine-grained overlap machinery hides (mode-dependent, and
/// only the streamed base transfer is hideable). Returns the neighbour
/// links plus the interleaved wrap hand-off (last physical stage back to
/// the first, a long-haul reshard between those two chip groups).
pub(crate) fn stage_links(
    stages: &[StageSim],
    groups: &[&ChipGroup],
    model: &ModelShape,
    micro_tokens: usize,
    opts: &SimOptions,
) -> (Vec<f64>, f64) {
    let act_bytes = micro_tokens * model.hidden * 2; // bf16 activations
    let eff = if opts.fine_overlap { overlap_effectiveness(opts.comm) } else { 0.0 };
    let hop = |src_stage: &StageSim, dst_stage: &StageSim| {
        let src = &groups[src_stage.group].spec;
        let dst = &groups[dst_stage.group].spec;
        let cost = reshard_cost(
            opts.reshard, opts.comm, act_bytes,
            src, src_stage.s_tp, dst, dst_stage.s_tp,
            opts.nic_assignment,
        );
        cost.total - eff * cost.overlappable
    };
    let mut link = vec![0.0f64; stages.len().saturating_sub(1)];
    for s in 0..link.len() {
        link[s] = hop(&stages[s], &stages[s + 1]);
    }
    let wrap_link = if stages.len() > 1 {
        hop(&stages[stages.len() - 1], &stages[0])
    } else {
        0.0
    };
    (link, wrap_link)
}

/// Simulate a serialized [`crate::plan::ExecutionPlan`] — the plan-centric
/// entry point; a free-function alias for
/// [`crate::plan::ExecutionPlan::simulate`].
pub fn simulate_plan(plan: &crate::plan::ExecutionPlan) -> SimResult {
    plan.simulate()
}

/// What [`simulate_plan_with_faults`] returns: one simulated iteration per
/// executed step, truncated at the first chip death.
#[derive(Clone, Debug)]
pub struct FaultSimResult {
    /// Seconds of each executed step (`step_seconds[i]` is step
    /// `i`'s iteration time under that step's fault factors).
    pub step_seconds: Vec<f64>,
    /// Sum of [`FaultSimResult::step_seconds`].
    pub total_seconds: f64,
    /// `Some(step)` when a [`crate::elastic::FaultKind::ChipDeath`] halted
    /// the run at the start of `step` (steps `0..step` executed); `None`
    /// when every requested step ran.
    pub halted_at: Option<usize>,
}

/// Simulate `steps` training steps of a plan under a fault schedule — the
/// simulator's view of the elastic loop's fault layer, mirroring the
/// virtual coordinator's semantics ([`crate::coordinator::train_virtual`]):
/// a slowdown multiplies a stage's compute times, NIC degradation
/// multiplies its outgoing activation hop and its exposed DP-sync slice,
/// and a chip death drains the run at that step boundary. Faults scale
/// *time only* — the simulator has no numerics to disturb, exactly like
/// the virtual coordinator whose losses stay bit-identical under faults.
///
/// A hop is charged its upstream (activation-sending) stage's NIC factor;
/// gradients re-use the same link table, so a degraded stage also slows
/// the backward hand-off it forwards activations over.
pub fn simulate_plan_with_faults(
    plan: &crate::plan::ExecutionPlan,
    faults: &FaultPlan,
    steps: usize,
) -> Result<FaultSimResult> {
    let workers = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    simulate_plan_with_faults_workers(plan, faults, steps, workers)
}

/// Below this many faulty steps the scoped-thread fan-out costs more than
/// it saves; the fault driver falls back to the sequential loop (which is
/// bit-identical anyway).
const MIN_PARALLEL_STEPS: usize = 4;

/// [`simulate_plan_with_faults`] with an explicit worker count — the
/// deterministic parallel driver. Faulty steps are priced concurrently by
/// per-worker clones of one shared [`SimEngine`] over contiguous slot
/// ranges and merged back in step order, so the result is bit-identical
/// for every worker count (each step's simulation reads only the engine's
/// iteration-invariant base tables; the scratch arenas are fully
/// reinitialized per run).
pub fn simulate_plan_with_faults_workers(
    plan: &crate::plan::ExecutionPlan,
    faults: &FaultPlan,
    steps: usize,
    workers: usize,
) -> Result<FaultSimResult> {
    let mut engine = SimEngine::for_plan(plan);
    let s_n = engine.stages();
    faults.validate(s_n)?;

    let (run_steps, halted_at) = match faults.first_death() {
        Some(death) if death.step < steps => (death.step, Some(death.step)),
        _ => (steps, None),
    };

    let factors: Vec<Vec<(f64, f64)>> = (0..run_steps)
        .map(|step| (0..s_n).map(|s| faults.factors_at(step, s)).collect())
        .collect();
    let is_healthy =
        |f: &Vec<(f64, f64)>| f.iter().all(|&(cf, nf)| cf == 1.0 && nf == 1.0);

    // Healthy steps all cost the same — simulate that case once.
    let healthy = if factors.iter().any(&is_healthy) {
        Some(engine.run().iteration_seconds)
    } else {
        None
    };

    let faulty: Vec<usize> =
        (0..run_steps).filter(|&i| !is_healthy(&factors[i])).collect();
    let workers = workers.max(1).min(faulty.len().max(1));
    let faulty_seconds: Vec<f64> = if workers <= 1 || faulty.len() < MIN_PARALLEL_STEPS {
        faulty
            .iter()
            .map(|&step| engine.run_scaled(&factors[step]).iteration_seconds)
            .collect()
    } else {
        let chunk = faulty.len().div_ceil(workers);
        let mut per_worker: Vec<Vec<f64>> = Vec::with_capacity(workers);
        thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let lo = w * chunk;
                let hi = ((w + 1) * chunk).min(faulty.len());
                if lo >= hi {
                    break;
                }
                let mut eng = engine.clone();
                let faulty = &faulty[lo..hi];
                let factors = &factors;
                handles.push(scope.spawn(move || {
                    faulty
                        .iter()
                        .map(|&step| eng.run_scaled(&factors[step]).iteration_seconds)
                        .collect::<Vec<f64>>()
                }));
            }
            // Fixed reduction order: worker 0's chunk first, then 1's, …
            for h in handles {
                per_worker.push(h.join().expect("fault-sim worker panicked"));
            }
        });
        per_worker.concat()
    };

    let mut step_seconds = Vec::with_capacity(run_steps);
    let mut next_faulty = 0usize;
    for f in &factors {
        if is_healthy(f) {
            step_seconds.push(healthy.expect("healthy memo populated above"));
        } else {
            step_seconds.push(faulty_seconds[next_faulty]);
            next_faulty += 1;
        }
    }
    Ok(FaultSimResult {
        total_seconds: step_seconds.iter().sum(),
        step_seconds,
        halted_at,
    })
}

/// Simulate several plans concurrently (one scoped worker per plan, one
/// engine each) and return the results in input order — the deterministic
/// fan-out behind the Table 9 ablation batch and any caller that prices
/// independent plan variants side by side. Parallel ≡ sequential
/// bit-for-bit: the plans share no state and the reduction order is fixed.
pub fn simulate_plans(plans: &[&crate::plan::ExecutionPlan]) -> Vec<SimResult> {
    let mut results: Vec<Option<SimResult>> = (0..plans.len()).map(|_| None).collect();
    thread::scope(|scope| {
        let mut handles = Vec::with_capacity(plans.len());
        for &plan in plans {
            handles.push(scope.spawn(move || SimEngine::for_plan(plan).run()));
        }
        for (slot, h) in handles.into_iter().enumerate() {
            results[slot] = Some(h.join().expect("plan-sim worker panicked"));
        }
    });
    results.into_iter().map(|r| r.expect("every plan simulated")).collect()
}

/// Fold per-stage clocks into the shared [`SimResult`] shape: optimizer
/// update appended per stage, critical stage by final clock, bubble from
/// its busy/idle split. Shared by the arena engine and the reference
/// executors so the two cannot diverge in the fold.
pub(crate) fn finish(
    stages: &[StageSim],
    clock: &[f64],
    busy: &[f64],
    exposed_comm: &[f64],
) -> SimResult {
    let s_n = stages.len();
    let mut iteration: f64 = 0.0;
    for s in 0..s_n {
        iteration = iteration.max(clock[s] + stages[s].t_update);
    }
    let crit = (0..s_n)
        .max_by(|&a, &b| {
            (clock[a] + stages[a].t_update)
                .partial_cmp(&(clock[b] + stages[b].t_update))
                .unwrap()
        })
        .unwrap();
    let bubble_fraction = 1.0 - busy[crit] / clock[crit];

    SimResult {
        iteration_seconds: iteration,
        busy: busy.to_vec(),
        bubble_fraction,
        exposed_comm: exposed_comm[crit],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommAlgo;
    use crate::costmodel::{evaluate, GroupPlan, Schedule, H2_100B};
    use crate::hetero::{experiment, homogeneous_baseline, ChipKind};

    fn table6_a_strategy() -> Strategy {
        Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
        }
    }

    #[test]
    fn sim_close_to_cost_model() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = table6_a_strategy();
        let sim = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        let cm = evaluate(&H2_100B, &groups, &strategy, 4096);
        let rel = (sim.iteration_seconds - cm.iteration_seconds).abs() / cm.iteration_seconds;
        assert!(rel < 0.15, "sim {} vs cost model {}", sim.iteration_seconds,
                cm.iteration_seconds);
    }

    #[test]
    fn bubble_fraction_matches_1f1b_theory() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = table6_a_strategy();
        let sim = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        // 1F1B bubble ≈ (pp-1)/(b + pp - 1) = 15/143 ≈ 0.105.
        assert!((sim.bubble_fraction - 15.0 / 143.0).abs() < 0.03,
                "bubble {}", sim.bubble_fraction);
    }

    #[test]
    fn interleaving_shrinks_the_bubble() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let f1b1 = table6_a_strategy();
        let mut il = table6_a_strategy();
        il.schedule = Schedule::Interleaved { virtual_stages: 2 }; // 6 layers/stage: divisible
        let base = simulate_iteration(&H2_100B, &groups, &f1b1, 4096, &SimOptions::default());
        let sim = simulate_iteration(&H2_100B, &groups, &il, 4096, &SimOptions::default());
        assert!(sim.bubble_fraction < base.bubble_fraction,
                "interleaved bubble {} vs 1f1b {}", sim.bubble_fraction, base.bubble_fraction);
        assert!(sim.iteration_seconds < base.iteration_seconds * 1.01,
                "interleaved {} vs 1f1b {}", sim.iteration_seconds, base.iteration_seconds);
        // Parity with the closed form's α = 1/v view of the same strategy.
        let cm = evaluate(&H2_100B, &groups, &il, 4096);
        let rel = (sim.iteration_seconds - cm.iteration_seconds).abs() / cm.iteration_seconds;
        assert!(rel < 0.35, "interleaved sim {} vs cost model {}",
                sim.iteration_seconds, cm.iteration_seconds);
    }

    #[test]
    fn zero_bubble_shrinks_the_bubble() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let f1b1 = table6_a_strategy();
        let mut zb = table6_a_strategy();
        zb.schedule = Schedule::ZeroBubbleV;
        let base = simulate_iteration(&H2_100B, &groups, &f1b1, 4096, &SimOptions::default());
        let sim = simulate_iteration(&H2_100B, &groups, &zb, 4096, &SimOptions::default());
        assert!(sim.bubble_fraction < base.bubble_fraction,
                "zb bubble {} vs 1f1b {}", sim.bubble_fraction, base.bubble_fraction);
        assert!(sim.iteration_seconds <= base.iteration_seconds * 1.001,
                "zb {} vs 1f1b {}", sim.iteration_seconds, base.iteration_seconds);
        // Parity with the closed form's α = 0 view: the residual warm-up
        // bubble the weight-gradient phase cannot fill is unmodeled there.
        let cm = evaluate(&H2_100B, &groups, &zb, 4096);
        let rel = (sim.iteration_seconds - cm.iteration_seconds).abs() / cm.iteration_seconds;
        assert!(rel < 0.35, "zb sim {} vs cost model {}",
                sim.iteration_seconds, cm.iteration_seconds);
    }

    #[test]
    fn every_schedule_completes_heterogeneous_pipelines() {
        let exp = experiment("exp-a-1").unwrap();
        let groups = exp.cluster.groups_by_memory_desc();
        for schedule in Schedule::SEARCH_SPACE {
            let strategy = Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 16, s_tp: 4, layers: 40, recompute: false },
                    GroupPlan { s_pp: 16, s_tp: 4, layers: 40, recompute: true },
                    GroupPlan { s_pp: 16, s_tp: 4, layers: 16, recompute: true },
                ],
            };
            let sim =
                simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
            assert!(sim.iteration_seconds.is_finite(), "{schedule}");
            assert!(sim.busy.iter().all(|&x| x > 0.0), "{schedule}");
        }
    }

    #[test]
    fn tcp_slower_than_ddr_end_to_end() {
        let exp = experiment("exp-a-1").unwrap();
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![
                GroupPlan { s_pp: 16, s_tp: 4, layers: 40, recompute: false },
                GroupPlan { s_pp: 16, s_tp: 4, layers: 40, recompute: true },
                GroupPlan { s_pp: 16, s_tp: 4, layers: 16, recompute: true },
            ],
        };
        let ddr = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        let tcp = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions {
            comm: CommMode::TcpCpu,
            fine_overlap: false,
            ..Default::default()
        });
        assert!(tcp.iteration_seconds > ddr.iteration_seconds);
    }

    #[test]
    fn overlap_reduces_iteration_time() {
        let exp = experiment("exp-a-1").unwrap();
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 2,
            micro_batches: 256,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![
                GroupPlan { s_pp: 32, s_tp: 4, layers: 40, recompute: false },
                GroupPlan { s_pp: 32, s_tp: 4, layers: 40, recompute: true },
                GroupPlan { s_pp: 32, s_tp: 4, layers: 16, recompute: true },
            ],
        };
        let with = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        let without = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions {
            fine_overlap: false,
            ..Default::default()
        });
        assert!(without.iteration_seconds > with.iteration_seconds);
    }

    #[test]
    fn non_affine_nic_mapping_slows_the_dp_sync_too() {
        // The simulator prices the DP collective under the run's NIC
        // policy: flipping to non-affinity must cost iteration time (on
        // top of the resharding penalty it already modeled).
        let exp = homogeneous_baseline(ChipKind::B);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: true }],
        };
        let aff = simulate_iteration(&H2_100B, &groups, &strategy, 4096,
                                     &SimOptions::default());
        let non = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions {
            nic_assignment: NicAssignment::NonAffinity,
            ..Default::default()
        });
        assert!(non.iteration_seconds > aff.iteration_seconds,
                "non-affinity {} !> affinity {}",
                non.iteration_seconds, aff.iteration_seconds);
    }

    #[test]
    fn hierarchical_collective_shrinks_iteration_time() {
        // Chip B at TP 4 co-locates only 2 of the 4 DP replicas per node,
        // so the DP sync crosses nodes: the two-level collective must beat
        // the flat ring in the discrete-event view exactly as it does in
        // the closed form.
        let exp = homogeneous_baseline(ChipKind::B);
        let groups = exp.cluster.groups_by_memory_desc();
        let mk = |comm_algo| Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: Schedule::OneF1B,
            comm_algo,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: true }],
        };
        let ring = simulate_iteration(&H2_100B, &groups, &mk(CommAlgo::Ring), 4096,
                                      &SimOptions::default());
        let hier = simulate_iteration(&H2_100B, &groups, &mk(CommAlgo::Hierarchical), 4096,
                                      &SimOptions::default());
        assert!(hier.iteration_seconds < ring.iteration_seconds,
                "hier {} !< ring {}", hier.iteration_seconds, ring.iteration_seconds);
    }

    fn faulted_fixture_plan() -> crate::plan::ExecutionPlan {
        // In-lib mirror of the integration suites' mixed-vendor fixture.
        let model = ModelShape {
            n_layers: 8,
            hidden: 2048,
            n_heads: 16,
            n_kv_heads: 16,
            intermediate: 8192,
            vocab: 32000,
            seq_len: 4096,
            n_experts: 0,
            top_k: 0,
            expert_intermediate: 0,
        };
        let cluster = crate::hetero::Cluster::new(
            "parity-2stage",
            vec![(ChipKind::A, 16), (ChipKind::B, 16)],
        );
        crate::plan::PlanBuilder::new("parity")
            .model(model)
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 8,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: false },
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: true },
                ],
            })
            .gbs_tokens(4 * 8 * 4096)
            .build()
            .unwrap()
    }

    #[test]
    fn fault_free_steps_match_the_healthy_iteration_bit_for_bit() {
        use crate::elastic::FaultPlan;
        let plan = faulted_fixture_plan();
        let healthy = simulate_plan(&plan).iteration_seconds;
        let r = simulate_plan_with_faults(&plan, &FaultPlan::none(), 4).unwrap();
        assert_eq!(r.halted_at, None);
        assert_eq!(r.step_seconds.len(), 4);
        assert!(r.step_seconds.iter().all(|&t| t == healthy));
        assert_eq!(r.total_seconds, healthy * 4.0);
    }

    #[test]
    fn slowdown_and_nic_degradation_cost_time_until_recovery() {
        use crate::elastic::{FaultEvent, FaultKind, FaultPlan};
        let plan = faulted_fixture_plan();
        let healthy = simulate_plan(&plan).iteration_seconds;
        let faults = FaultPlan {
            seed: 7,
            events: vec![
                FaultEvent { step: 1, stage: 1, kind: FaultKind::Slowdown { factor: 2.0 } },
                FaultEvent { step: 1, stage: 0, kind: FaultKind::NicDegrade { factor: 3.0 } },
                FaultEvent { step: 3, stage: 1, kind: FaultKind::Recover },
                FaultEvent { step: 3, stage: 0, kind: FaultKind::Recover },
            ],
        };
        let r = simulate_plan_with_faults(&plan, &faults, 4).unwrap();
        assert_eq!(r.halted_at, None);
        assert_eq!(r.step_seconds[0], healthy, "pre-fault step must be healthy");
        assert!(r.step_seconds[1] > healthy, "degraded step not slower");
        assert_eq!(r.step_seconds[1], r.step_seconds[2], "persistent fault drifted");
        assert_eq!(r.step_seconds[3], healthy, "recovery must restore the clock");
    }

    #[test]
    fn chip_death_truncates_the_simulated_run() {
        use crate::elastic::{FaultEvent, FaultKind, FaultPlan};
        let plan = faulted_fixture_plan();
        let faults = FaultPlan {
            seed: 7,
            events: vec![FaultEvent {
                step: 2,
                stage: 1,
                kind: FaultKind::ChipDeath { nodes: 1 },
            }],
        };
        let r = simulate_plan_with_faults(&plan, &faults, 6).unwrap();
        assert_eq!(r.halted_at, Some(2));
        assert_eq!(r.step_seconds.len(), 2);
        // An out-of-range stage is rejected by the plan check.
        let bad = FaultPlan {
            seed: 7,
            events: vec![FaultEvent { step: 0, stage: 9, kind: FaultKind::Recover }],
        };
        assert!(simulate_plan_with_faults(&plan, &bad, 2).is_err());
    }

    #[test]
    fn all_ops_complete() {
        let exp = homogeneous_baseline(ChipKind::B);
        let groups = exp.cluster.groups_by_memory_desc();
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 8,
            micro_batches: 64,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 8, s_tp: 4, layers: 96, recompute: true }],
        };
        let sim = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        assert!(sim.iteration_seconds.is_finite());
        assert!(sim.busy.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn fault_driver_is_worker_count_invariant() {
        use crate::elastic::FaultPlan;
        let plan = faulted_fixture_plan();
        let faults = FaultPlan::generate(11, 12, 2, false);
        let a = simulate_plan_with_faults_workers(&plan, &faults, 12, 1).unwrap();
        let b = simulate_plan_with_faults_workers(&plan, &faults, 12, 4).unwrap();
        assert_eq!(a.halted_at, b.halted_at);
        assert_eq!(a.step_seconds, b.step_seconds);
        assert_eq!(a.total_seconds, b.total_seconds);
    }

    #[test]
    fn simulate_plans_matches_the_sequential_entry_point() {
        let plan = faulted_fixture_plan();
        let one = simulate_plan(&plan);
        for r in simulate_plans(&[&plan, &plan, &plan]) {
            assert_eq!(r.iteration_seconds, one.iteration_seconds);
            assert_eq!(r.busy, one.busy);
            assert_eq!(r.exposed_comm, one.exposed_comm);
        }
    }
}
