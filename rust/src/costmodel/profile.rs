//! Layer-wise analytic profiler.
//!
//! Stands in for the paper's auto-profiler (§4.3.2): where the authors
//! measure `t_fwd`, `t_bwd`, `t_recomp`, `t_update` per chip and TP size on
//! real hardware, we derive them from the chip catalog with a
//! roofline-style model:
//!
//! * dense compute at `fp16_tflops × mfu` (mfu calibrated per chip against
//!   the paper's own Table 6 homogeneous measurements),
//! * TP collective time on the intra-node fabric (2 allreduces each for
//!   forward and backward per layer, §2.2),
//! * ZeRO-1 optimizer update: Adam math + the non-overlapped slice of the
//!   DP gradient synchronization, priced by the DiComm collective engine
//!   ([`crate::comm::allreduce_cost`]) under the strategy's [`CommAlgo`]
//!   over the stage's DP-group topology.
//!
//! The same numbers can alternatively be calibrated from real PJRT stage
//! executions (`h2 profile`), which is what keeps HeteroAuto honest: it
//! only ever consumes this table, exactly like the paper's searcher.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

use crate::comm::{allreduce_cost, alltoall_cost, AllToAllAlgo, CommAlgo, CommTopology};
use crate::hetero::{ChipKind, ChipSpec};
use crate::topology::NicAssignment;

use super::ModelShape;

/// Profiled per-layer times (seconds) for one (chip, TP, DP) combination.
///
/// Equality is exact (bit-level on every field) — what the profile-cache
/// parity tests rely on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerProfile {
    /// Forward seconds per layer per microbatch.
    pub t_fwd: f64,
    /// Backward seconds per layer per microbatch.
    pub t_bwd: f64,
    /// Activation-recompute seconds per layer (= forward).
    pub t_recompute: f64,
    /// Optimizer step + non-overlapped DP gradient sync, per layer.
    pub t_update: f64,
    /// The exposed DP gradient-sync slice alone (already included in
    /// [`LayerProfile::t_update`]) — the part the coordinator replaces
    /// with its executed collective's own accounting.
    pub t_dp_sync: f64,
    /// Extra per-layer time *per iteration* if optimizer states are
    /// offloaded to host (fp32 shard traffic over PCIe).
    pub t_offload: f64,
    /// Extra per-layer time *per microbatch* when gradients stream to host
    /// (synchronous ZeRO-Offload-style stall).
    pub t_offload_micro: f64,
    /// Parameters held per chip for one layer (after TP sharding).
    pub params_per_chip: f64,
}

/// Fraction of the DP gradient allreduce hidden under backward compute
/// (the paper overlaps gradient sync with backward; §4.3.2's t_update is
/// only the exposed part).
pub const DP_OVERLAP: f64 = 0.7;

/// Adam FLOPs per parameter (fp32 master-weight update).
const ADAM_FLOPS: f64 = 12.0;

/// Host↔device PCIe bandwidth for offloaded optimizer traffic, bytes/s.
const PCIE_OFFLOAD_BPS: f64 = 12.0e9;

/// Token-routing imbalance factor: the hottest expert-parallel rank's
/// all-to-all payload and expert compute relative to a perfectly balanced
/// router. A deterministic stand-in for the load factor real MoE runs
/// measure (auxiliary-loss-balanced routers hover near this); applied
/// only once experts are actually sharded (`s_ep > 1`) — with every
/// expert resident (`s_ep == 1`) routing moves no tokens between chips,
/// so skew cancels out within the chip.
pub const MOE_IMBALANCE: f64 = 1.2;

/// Analytic per-layer profile for one (chip, TP, DP) combination —
/// the roofline stand-in for the paper's measured auto-profiler table.
/// DP gradient sync is priced as a flat ring under NIC affinity (the
/// pre-engine behaviour); see [`profile_layer_comm`] for the
/// algorithm- and NIC-policy-aware variant.
pub fn profile_layer(
    spec: &ChipSpec,
    model: &ModelShape,
    tp: usize,
    micro_tokens: usize,
    dp: usize,
) -> LayerProfile {
    profile_layer_comm(spec, model, tp, micro_tokens, dp, 1, CommAlgo::Ring,
                       NicAssignment::Affinity)
}

/// [`profile_layer`] with an explicit DP-gradient collective algorithm,
/// expert-parallel degree and NIC-assignment policy: the exposed DP-sync
/// slice of `t_update` prices `comm_algo` with the closed-form engine
/// over the stage's DP-group topology ([`CommTopology::dp_group`]), whose
/// inter-node link carries the Table 3 per-flow bandwidth under `assign`.
/// For MoE shapes the routed expert FFNs add compute, and `ep > 1` adds
/// the per-layer token dispatch/combine all-to-alls over the EP group
/// (priced by [`alltoall_cost`] under [`AllToAllAlgo::Auto`]) with the
/// hottest rank carrying [`MOE_IMBALANCE`]× the balanced share.
#[allow(clippy::too_many_arguments)]
pub fn profile_layer_comm(
    spec: &ChipSpec,
    model: &ModelShape,
    tp: usize,
    micro_tokens: usize,
    dp: usize,
    ep: usize,
    comm_algo: CommAlgo,
    assign: NicAssignment,
) -> LayerProfile {
    let tpf = tp as f64;
    let sustained = spec.sustained_tflops() * 1e12;
    // The expert bank is EP-sharded across `ep` of the DP replicas (then
    // TP-sharded like the dense trunk) — the memory/update/sync pool a
    // chip actually holds. Dense models contribute exactly 0.
    let params_per_chip =
        (model.params_per_layer() + model.expert_params_per_layer() / ep as f64) / tpf;

    // Dense compute: fwd = 2·params + attention; bwd = 2×fwd.
    let fwd_flops = micro_tokens as f64 * model.fwd_flops_per_token_layer() / tpf;
    let t_fwd_dense = fwd_flops / sustained;

    // TP collectives: two ring allreduces per layer in fwd (and two in bwd)
    // of the full activation (§2.2), on the TP island's uniform bandwidth.
    let t_tp_ar = if tp > 1 {
        let island = spec.intra_node.uniform_island(spec.chips_per_node);
        let bw = spec.intra_node.bandwidth_gbps(0, (tp - 1).min(island - 1)) * 1e9;
        let bytes = micro_tokens as f64 * model.hidden as f64 * 2.0; // bf16
        2.0 * (2.0 * (tpf - 1.0) / tpf) * bytes / bw + 2.0 * 3.0e-6
    } else {
        0.0
    };

    // MoE: each token routes through its `top_k` expert FFNs on top of the
    // dense trunk; with the experts sharded over `ep` ranks the tokens
    // cross the EP group twice per direction (dispatch + combine), priced
    // by the all-to-all engine with the hottest rank carrying
    // [`MOE_IMBALANCE`]× the balanced payload and compute. Every term is
    // exactly 0.0 for dense models, keeping their profiles bit-identical.
    let (t_moe_fwd, t_moe_a2a) = if model.n_experts > 0 {
        let imbalance = if ep > 1 { MOE_IMBALANCE } else { 1.0 };
        let expert_flops = micro_tokens as f64
            * model.top_k as f64
            * 6.0
            * model.hidden as f64
            * model.expert_intermediate as f64
            / tpf;
        let t_expert = imbalance * expert_flops / sustained;
        let a2a = if ep > 1 {
            let topo = CommTopology::dp_group(spec, ep, tp, assign);
            let bytes = (imbalance
                * micro_tokens as f64
                * model.top_k as f64
                * model.hidden as f64
                * 2.0) as usize; // bf16 routed activations
            2.0 * alltoall_cost(AllToAllAlgo::Auto, bytes, &topo).seconds
        } else {
            0.0
        };
        (t_expert, a2a)
    } else {
        (0.0, 0.0)
    };

    let t_fwd = t_fwd_dense + t_tp_ar + t_moe_fwd + t_moe_a2a;
    let t_bwd = 2.0 * t_fwd_dense + t_tp_ar + 2.0 * t_moe_fwd + t_moe_a2a;
    let t_recompute = t_fwd;

    // Optimizer: Adam math (memory-bound on chip, folded into sustained
    // throughput) + exposed DP sync of bf16 gradients, priced by the
    // DiComm engine under the strategy's collective algorithm over this
    // stage's DP-group topology (co-located replicas on the intra fabric,
    // scattered ones on the Table 3 per-flow NIC path).
    let t_adam = params_per_chip * ADAM_FLOPS / sustained / dp as f64; // ZeRO-1 shard
    let t_dp_sync = if dp > 1 {
        let topo = CommTopology::dp_group(spec, dp, tp, assign);
        let grad_bytes = (params_per_chip * 2.0) as usize;
        allreduce_cost(comm_algo, grad_bytes, &topo).seconds * (1.0 - DP_OVERLAP)
    } else {
        0.0
    };
    let t_update = t_adam + t_dp_sync;

    // Offload: grads to host + updated params back (bf16 each way) plus the
    // fp32 shard traffic, serialized on PCIe.
    let t_offload = params_per_chip * 8.0 / PCIE_OFFLOAD_BPS;
    // Per microbatch, bf16 gradients stream down synchronously.
    let t_offload_micro = params_per_chip * 2.0 / PCIE_OFFLOAD_BPS;

    LayerProfile { t_fwd, t_bwd, t_recompute, t_update, t_dp_sync, t_offload,
                   t_offload_micro, params_per_chip }
}

/// One distinct profile shape: everything [`profile_layer_comm`] depends on.
type ProfileKey =
    (ModelShape, ChipKind, usize, usize, usize, usize, CommAlgo, NicAssignment);

/// Shared, thread-safe memoization of [`profile_layer_comm`].
///
/// HeteroAuto's hot path evaluates the same per-layer profile at every DFS
/// leaf and sharding-refinement round; the number of *distinct* shapes —
/// `(model, chip kind, s_tp, micro_tokens, s_dp, s_ep, comm algo, NIC
/// policy)` tuples — is tiny by comparison (tens per search, even at
/// paper scale).
/// A cache hit returns the stored [`LayerProfile`] verbatim, so cached and
/// uncached paths are bit-identical (property-tested).
///
/// The key includes the [`ChipKind`] but not the numbers behind it, so a
/// cache must not outlive a [`crate::hetero::register_custom`] call that
/// redefines a custom chip — the search creates one cache per invocation,
/// which also keeps entries from piling up across unrelated models.
#[derive(Debug, Default)]
pub struct ProfileCache {
    map: RwLock<HashMap<ProfileKey, LayerProfile>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl ProfileCache {
    /// An empty cache. Cheap; intended to live for one search/evaluation.
    pub fn new() -> ProfileCache {
        ProfileCache::default()
    }

    /// The cached (or freshly computed and stored) [`profile_layer_comm`]
    /// result for this shape — bit-identical to calling the profiler
    /// directly.
    #[allow(clippy::too_many_arguments)]
    pub fn profile(
        &self,
        spec: &ChipSpec,
        model: &ModelShape,
        tp: usize,
        micro_tokens: usize,
        dp: usize,
        ep: usize,
        comm_algo: CommAlgo,
        assign: NicAssignment,
    ) -> LayerProfile {
        let key = (*model, spec.kind, tp, micro_tokens, dp, ep, comm_algo, assign);
        if let Some(p) = self.map.read().expect("profile cache poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *p;
        }
        // Compute outside any lock; a racing duplicate insert stores the
        // identical value (the profiler is deterministic), so last-write-
        // wins is harmless.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let p = profile_layer_comm(spec, model, tp, micro_tokens, dp, ep, comm_algo, assign);
        self.map.write().expect("profile cache poisoned").insert(key, p);
        p
    }

    /// Distinct shapes profiled so far.
    pub fn len(&self) -> usize {
        self.map.read().expect("profile cache poisoned").len()
    }

    /// Whether nothing has been profiled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache so far.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to run the profiler so far.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::H2_100B;
    use crate::hetero::{spec, ChipKind};

    #[test]
    fn bwd_is_twice_fwd_dense() {
        let p = profile_layer(&spec(ChipKind::A), &H2_100B, 1, 4096, 1);
        assert!((p.t_bwd / p.t_fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tp_reduces_compute_time_sublinearly() {
        let s = spec(ChipKind::A);
        let p1 = profile_layer(&s, &H2_100B, 1, 4096, 1);
        let p4 = profile_layer(&s, &H2_100B, 4, 4096, 1);
        assert!(p4.t_fwd < p1.t_fwd);
        assert!(p4.t_fwd > p1.t_fwd / 4.0); // allreduce overhead
    }

    #[test]
    fn faster_chip_has_smaller_times() {
        let pa = profile_layer(&spec(ChipKind::A), &H2_100B, 4, 4096, 1);
        let pd = profile_layer(&spec(ChipKind::D), &H2_100B, 4, 4096, 1);
        assert!(pd.t_fwd < pa.t_fwd); // D has more sustained TFLOPS
    }

    #[test]
    fn dp_sync_grows_update_time() {
        let s = spec(ChipKind::C);
        let p1 = profile_layer(&s, &H2_100B, 4, 4096, 1);
        let p8 = profile_layer(&s, &H2_100B, 4, 4096, 8);
        assert!(p8.t_update > p1.t_update);
    }

    #[test]
    fn hierarchical_dp_sync_beats_ring_on_multi_node_groups() {
        // Chip B, TP 4: only 2 of the 4 DP replicas fit per 8-chip node,
        // so the DP ring crosses nodes — the two-level collective keeps
        // most hops on the intra fabric and must shrink t_update.
        let s = spec(ChipKind::B);
        let aff = NicAssignment::Affinity;
        let ring = profile_layer_comm(&s, &H2_100B, 4, 4096, 4, 1, CommAlgo::Ring, aff);
        let hier = profile_layer_comm(&s, &H2_100B, 4, 4096, 4, 1, CommAlgo::Hierarchical, aff);
        assert!(hier.t_update < ring.t_update,
                "hier {} !< ring {}", hier.t_update, ring.t_update);
        // Auto never loses to any concrete algorithm.
        let auto = profile_layer_comm(&s, &H2_100B, 4, 4096, 4, 1, CommAlgo::Auto, aff);
        for algo in CommAlgo::CONCRETE {
            let p = profile_layer_comm(&s, &H2_100B, 4, 4096, 4, 1, algo, aff);
            assert!(auto.t_update <= p.t_update, "{algo}");
        }
        // Compute terms are untouched by the collective choice.
        assert_eq!(ring.t_fwd, hier.t_fwd);
        assert_eq!(ring.t_bwd, hier.t_bwd);
        // A non-affine NIC mapping degrades the cross-node DP sync.
        let non = profile_layer_comm(&s, &H2_100B, 4, 4096, 4, 1, CommAlgo::Ring,
                                     NicAssignment::NonAffinity);
        assert!(non.t_update > ring.t_update,
                "non-affinity {} !> affinity {}", non.t_update, ring.t_update);
    }

    #[test]
    fn sensible_magnitudes_for_100b() {
        // A layer of the 100B on Chip-A/TP4 should be O(10ms), not O(1s).
        let p = profile_layer(&spec(ChipKind::A), &H2_100B, 4, 4096, 4);
        assert!(p.t_fwd > 1e-3 && p.t_fwd < 0.1, "t_fwd {}", p.t_fwd);
    }

    #[test]
    fn cached_profiles_are_bit_identical_to_uncached() {
        // Property: for arbitrary shapes, the cache returns exactly what
        // the profiler computes — on first fill and on every hit after.
        use crate::costmodel::{H2_100B, H2_20B};
        use crate::util::prop;
        use crate::util::rng::Rng;

        let cache = ProfileCache::new();
        prop::check(200, |rng: &mut Rng| {
            let kinds = [ChipKind::A, ChipKind::B, ChipKind::C, ChipKind::D, ChipKind::A100];
            let s = spec(*rng.choose(&kinds));
            let r = rng.f64();
            let model = if r < 0.4 {
                H2_100B
            } else if r < 0.8 {
                H2_20B
            } else {
                crate::costmodel::H2_MOE
            };
            let tp = 1usize << rng.usize(0, 5); // 1..16
            let micro_tokens = *rng.choose(&[1024usize, 2048, 4096]);
            let dp = rng.usize(1, 65);
            let ep = if model.is_moe() { *rng.choose(&[1usize, 2, 4, 8]) } else { 1 };
            let algo = *rng.choose(&CommAlgo::ALL);
            let assign = if rng.f64() < 0.5 {
                NicAssignment::Affinity
            } else {
                NicAssignment::NonAffinity
            };
            let direct = profile_layer_comm(&s, &model, tp, micro_tokens, dp, ep, algo, assign);
            let first = cache.profile(&s, &model, tp, micro_tokens, dp, ep, algo, assign);
            let hit = cache.profile(&s, &model, tp, micro_tokens, dp, ep, algo, assign);
            prop::assert_prop(
                first == direct && hit == direct,
                format!("cache diverged for {s:?} tp={tp} dp={dp} ep={ep} {algo} {assign:?}"),
            )
        });
        assert!(!cache.is_empty());
        assert!(cache.len() <= 200);
    }

    #[test]
    fn moe_layer_costs_more_than_its_dense_trunk() {
        use crate::costmodel::{H2_20B, H2_MOE};
        // Same trunk geometry class, same chip/TP: the routed experts add
        // both compute time and resident parameters.
        let s = spec(ChipKind::A);
        let dense = profile_layer(&s, &H2_20B, 4, 4096, 4);
        let moe = profile_layer(&s, &H2_MOE, 4, 4096, 4);
        assert!(moe.t_fwd > dense.t_fwd, "moe {} !> dense {}", moe.t_fwd, dense.t_fwd);
        assert!(moe.params_per_chip > 2.0 * dense.params_per_chip);
    }

    #[test]
    fn ep_shards_expert_params_and_prices_the_alltoall() {
        use crate::costmodel::H2_MOE;
        let s = spec(ChipKind::A);
        let aff = NicAssignment::Affinity;
        let ep1 = profile_layer_comm(&s, &H2_MOE, 4, 4096, 8, 1, CommAlgo::Ring, aff);
        let ep8 = profile_layer_comm(&s, &H2_MOE, 4, 4096, 8, 8, CommAlgo::Ring, aff);
        // EP=8 keeps 1/8th of the expert bank per replica...
        assert!(ep8.params_per_chip < ep1.params_per_chip / 2.0);
        // ...but pays the dispatch/combine all-to-alls plus the hot-rank
        // imbalance on expert compute, which EP=1 (all experts resident,
        // no tokens cross chips) avoids entirely.
        assert!(
            ep8.t_fwd > ep1.t_fwd,
            "ep8 fwd {} should pay a2a over ep1's local routing {}",
            ep8.t_fwd,
            ep1.t_fwd
        );
        // The lighter resident shard also shrinks the optimizer/offload
        // terms that scale with params_per_chip.
        assert!(ep8.t_offload < ep1.t_offload);
    }

    #[test]
    fn dense_profiles_ignore_the_ep_axis_bit_for_bit() {
        // For a dense model every MoE term is literally 0.0, so ep is inert
        // and the legacy wrapper is bit-identical to the full call.
        let s = spec(ChipKind::B);
        let aff = NicAssignment::Affinity;
        let legacy = profile_layer(&s, &H2_100B, 4, 4096, 4);
        let full = profile_layer_comm(&s, &H2_100B, 4, 4096, 4, 1, CommAlgo::Ring, aff);
        assert_eq!(legacy, full);
    }
}
