//! First-class pipeline schedules (§4.3.2's bubble coefficient made real).
//!
//! The paper folds the schedule into a single coefficient `α` (1.0 = 1F1B,
//! 0.0 = ZB-V). [`Schedule`] replaces that scalar throughout the crate so
//! both evaluation paths can distinguish schedules properly:
//!
//! * the closed-form cost model scales its bubble term by
//!   [`Schedule::bubble_coefficient`] and its activation-residency term by
//!   [`Schedule::activation_residency`],
//! * the discrete-event simulator executes a real issue order per variant
//!   (see [`crate::sim::pipeline`]),
//! * HeteroAuto searches over schedules as an extra DFS dimension
//!   ([`crate::auto::SearchConfig::schedules`]).
//!
//! Schedules serialize as compact tokens (`1f1b`, `interleaved:V`, `zbv`)
//! in plan files, configs and on the CLI (`--schedule`).

use std::fmt;

/// A pipeline-parallel execution schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Schedule {
    /// Classic one-forward-one-backward: bubble fraction
    /// `(pp − 1) / (b + pp − 1)`, the paper's `α = 1` reference point.
    #[default]
    OneF1B,
    /// Interleaved 1F1B (Megatron-style virtual pipeline): each physical
    /// stage hosts `virtual_stages` layer chunks, shrinking the bubble by
    /// that factor at the price of higher activation residency and extra
    /// inter-stage traffic. `virtual_stages` must be ≥ 2 and divide every
    /// stage's layer count.
    Interleaved {
        /// Virtual chunks per physical stage (Megatron's `v`).
        virtual_stages: usize,
    },
    /// Zero-bubble schedule (ZB family): backward is split into an
    /// input-gradient phase on the critical path and a weight-gradient
    /// phase that fills what would otherwise be bubble, approaching the
    /// paper's `α = 0` limit while keeping 1F1B-level activation memory.
    ZeroBubbleV,
}

impl Schedule {
    /// The three variants HeteroAuto searches by default (interleaving at
    /// the common `v = 2`).
    pub const SEARCH_SPACE: [Schedule; 3] = [
        Schedule::OneF1B,
        Schedule::Interleaved { virtual_stages: 2 },
        Schedule::ZeroBubbleV,
    ];

    /// The §4.3.2 bubble coefficient `α`: the fraction of one full
    /// pipeline sweep (`Σ_{j≠i} T_comp,j`) the critical stage spends idle.
    /// 1F1B pays it in full, interleaving divides it by the virtual-stage
    /// count, and the zero-bubble schedule fills it with weight-gradient
    /// work.
    pub fn bubble_coefficient(&self) -> f64 {
        match *self {
            Schedule::OneF1B => 1.0,
            Schedule::Interleaved { virtual_stages } => 1.0 / virtual_stages.max(1) as f64,
            Schedule::ZeroBubbleV => 0.0,
        }
    }

    /// Virtual chunks per physical stage (1 for non-interleaved schedules).
    pub fn virtual_stages(&self) -> usize {
        match *self {
            Schedule::Interleaved { virtual_stages } => virtual_stages.max(1),
            _ => 1,
        }
    }

    /// Equivalent number of *full-stage* micro-batch activations resident
    /// at pipeline position `pos` (0-based) of `total_stages`.
    ///
    /// 1F1B keeps `min(b, pp − pos)` micro-batches queued during warm-up;
    /// the zero-bubble schedule is bounded by the same peak by design.
    /// Interleaving keeps `min(b·v, (v−1)·pp + pp − pos)` chunk
    /// activations of `1/v` stage depth each — equal at the first stage
    /// but strictly more on every later one, which is why interleaving
    /// multiplies activation residency in the memory model.
    pub fn activation_residency(
        &self,
        micro_batches: usize,
        total_stages: usize,
        pos: usize,
    ) -> f64 {
        let queue = total_stages.saturating_sub(pos).max(1);
        match *self {
            Schedule::OneF1B | Schedule::ZeroBubbleV => micro_batches.min(queue) as f64,
            Schedule::Interleaved { virtual_stages } => {
                let v = virtual_stages.max(1);
                let chunks = (micro_batches * v).min((v - 1) * total_stages + queue);
                chunks as f64 / v as f64
            }
        }
    }

    /// Canonical serialization token (`1f1b`, `interleaved:V`, `zbv`) —
    /// what plan files, configs and `--schedule` use.
    pub fn token(&self) -> String {
        match *self {
            Schedule::OneF1B => "1f1b".to_string(),
            Schedule::Interleaved { virtual_stages } => format!("interleaved:{virtual_stages}"),
            Schedule::ZeroBubbleV => "zbv".to_string(),
        }
    }

    /// Parse a canonical token. `interleaved` without a suffix means
    /// `interleaved:2`; interleaving below 2 virtual stages is rejected
    /// (that is just 1F1B).
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "1f1b" => Some(Schedule::OneF1B),
            "zbv" | "zb-v" => Some(Schedule::ZeroBubbleV),
            _ => {
                let rest = s.strip_prefix("interleaved")?;
                if rest.is_empty() {
                    return Some(Schedule::Interleaved { virtual_stages: 2 });
                }
                let v: usize = rest.strip_prefix(':')?.parse().ok()?;
                if v >= 2 {
                    Some(Schedule::Interleaved { virtual_stages: v })
                } else {
                    None
                }
            }
        }
    }

    /// Migration shim for pre-`Schedule` artifacts (plan files of version
    /// 1, legacy `alpha` config keys): map a scalar bubble coefficient to
    /// the nearest schedule. `α ≥ 0.75` reads as 1F1B, `α ≤ 0.25` as the
    /// zero-bubble schedule, anything between as interleaving with
    /// `round(1/α)` virtual stages.
    pub fn from_alpha(alpha: f64) -> Schedule {
        if !alpha.is_finite() || alpha >= 0.75 {
            Schedule::OneF1B
        } else if alpha <= 0.25 {
            Schedule::ZeroBubbleV
        } else {
            let v = (1.0 / alpha).round().clamp(2.0, 64.0) as usize;
            Schedule::Interleaved { virtual_stages: v }
        }
    }
}

impl fmt::Display for Schedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.token())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_roundtrip() {
        for s in [
            Schedule::OneF1B,
            Schedule::ZeroBubbleV,
            Schedule::Interleaved { virtual_stages: 2 },
            Schedule::Interleaved { virtual_stages: 7 },
        ] {
            assert_eq!(Schedule::parse(&s.token()), Some(s), "{s}");
        }
        assert_eq!(Schedule::parse("interleaved"),
                   Some(Schedule::Interleaved { virtual_stages: 2 }));
        assert_eq!(Schedule::parse("interleaved:1"), None);
        assert_eq!(Schedule::parse("bogus"), None);
    }

    #[test]
    fn bubble_coefficients_match_the_paper() {
        assert_eq!(Schedule::OneF1B.bubble_coefficient(), 1.0);
        assert_eq!(Schedule::ZeroBubbleV.bubble_coefficient(), 0.0);
        assert_eq!(Schedule::Interleaved { virtual_stages: 2 }.bubble_coefficient(), 0.5);
        assert_eq!(Schedule::Interleaved { virtual_stages: 4 }.bubble_coefficient(), 0.25);
    }

    #[test]
    fn alpha_migration_picks_nearest_schedule() {
        assert_eq!(Schedule::from_alpha(1.0), Schedule::OneF1B);
        assert_eq!(Schedule::from_alpha(0.0), Schedule::ZeroBubbleV);
        assert_eq!(Schedule::from_alpha(0.5),
                   Schedule::Interleaved { virtual_stages: 2 });
        assert_eq!(Schedule::from_alpha(f64::NAN), Schedule::OneF1B);
    }

    #[test]
    fn interleaving_keeps_first_stage_memory_but_raises_later_stages() {
        let il = Schedule::Interleaved { virtual_stages: 2 };
        let b = 128;
        let pp = 16;
        // First stage: residency matches 1F1B's full warm-up queue.
        let first_1f1b = Schedule::OneF1B.activation_residency(b, pp, 0);
        let first_il = il.activation_residency(b, pp, 0);
        assert!((first_il - first_1f1b).abs() < 1e-9, "{first_il} vs {first_1f1b}");
        // Later stages: interleaving holds strictly more.
        for pos in 1..pp {
            let r1 = Schedule::OneF1B.activation_residency(b, pp, pos);
            let ri = il.activation_residency(b, pp, pos);
            assert!(ri > r1, "pos {pos}: interleaved {ri} <= 1f1b {r1}");
        }
        // Zero-bubble stays within the 1F1B envelope.
        for pos in 0..pp {
            assert_eq!(Schedule::ZeroBubbleV.activation_residency(b, pp, pos),
                       Schedule::OneF1B.activation_residency(b, pp, pos));
        }
    }

    #[test]
    fn few_microbatches_cap_residency() {
        let il = Schedule::Interleaved { virtual_stages: 4 };
        // With b < pp the cap is b·v chunks = b full-stage equivalents.
        assert_eq!(il.activation_residency(3, 16, 0), 3.0);
        assert_eq!(Schedule::OneF1B.activation_residency(3, 16, 0), 3.0);
    }
}
