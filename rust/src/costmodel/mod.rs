//! HeteroAuto cost model (§4.3.2): iteration-time and memory estimation for
//! a candidate heterogeneous parallel strategy.
//!
//! `T = max_i ( b·T_comp,i + T_update,i + α·Σ_{j≠i} T_comp,j )`
//!
//! with `T_comp,i = ceil(l_i/s_pp,i)·(t_fwd + t_bwd + r_i·t_recomp)` and
//! `T_update,i = ceil(l_i/s_pp,i)·t_update(s_dp, s_tp,i)`. The paper folds
//! the pipeline schedule into the single bubble coefficient `α`; here the
//! schedule is first-class ([`Schedule`], carried by [`Strategy`]) and
//! supplies both `α` ([`Schedule::bubble_coefficient`]) and the
//! activation-residency term of the memory model
//! ([`Schedule::activation_residency`]).

pub mod memory;
pub mod profile;
pub mod schedule;

use crate::comm::CommAlgo;
use crate::hetero::{ChipGroup, Cluster};

pub use memory::{stage_memory_bytes, MemoryBreakdown};
pub use profile::{profile_layer, profile_layer_comm, LayerProfile, ProfileCache};
pub use schedule::Schedule;

/// Transformer shape consumed by the analytic model (Table 4 for the 100B).
/// Hashable so it can key the [`ProfileCache`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModelShape {
    /// Decoder layer count.
    pub n_layers: usize,
    /// Model (residual stream) width.
    pub hidden: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Key/value heads (GQA).
    pub n_kv_heads: usize,
    /// MLP intermediate width.
    pub intermediate: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Training sequence length in tokens.
    pub seq_len: usize,
    /// Routed experts per layer (0 = dense FFN, no MoE terms anywhere).
    pub n_experts: usize,
    /// Experts each token routes through (router top-k; 0 when dense).
    pub top_k: usize,
    /// Intermediate width of one expert FFN (0 when dense).
    pub expert_intermediate: usize,
}

/// Table 4: the 100B-parameter production model.
pub const H2_100B: ModelShape = ModelShape {
    n_layers: 96,
    hidden: 8192,
    n_heads: 64,
    n_kv_heads: 8,
    intermediate: 36864,
    vocab: 92544,
    seq_len: 4096,
    n_experts: 0,
    top_k: 0,
    expert_intermediate: 0,
};

/// The 20B model of the Fig 5 precision study.
pub const H2_20B: ModelShape = ModelShape {
    n_layers: 60,
    hidden: 5120,
    n_heads: 40,
    n_kv_heads: 8,
    intermediate: 13824,
    vocab: 92544,
    seq_len: 4096,
    n_experts: 0,
    top_k: 0,
    expert_intermediate: 0,
};

/// The sparse scenario model of the `exp-moe` fixture: the 20B trunk with
/// a routed 32-expert FFN bank per layer (2 active per token). The expert
/// bank multiplies *parameter* memory ~26x while each token's compute only
/// grows by the 2 routed experts — at EP=1 the per-stage optimizer state
/// no longer fits the fixture's chips and every layout degrades to PCIe
/// offload, exactly the cliff the EP axis (sharding expert memory across
/// DP replicas) removes.
pub const H2_MOE: ModelShape = ModelShape {
    n_layers: 60,
    hidden: 5120,
    n_heads: 40,
    n_kv_heads: 8,
    intermediate: 13824,
    vocab: 92544,
    seq_len: 4096,
    n_experts: 32,
    top_k: 2,
    expert_intermediate: 13824,
};

impl ModelShape {
    /// Attention head dimension (`hidden / n_heads`).
    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    /// Total key/value projection width (GQA-aware).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Parameters in one decoder layer.
    pub fn params_per_layer(&self) -> f64 {
        let h = self.hidden as f64;
        let kd = self.kv_dim() as f64;
        let i = self.intermediate as f64;
        2.0 * h * h + 2.0 * h * kd + 3.0 * h * i + 2.0 * h
    }

    /// Total parameter count (embeddings + layers + expert banks + final
    /// norm).
    pub fn total_params(&self) -> f64 {
        self.vocab as f64 * self.hidden as f64 * 2.0
            + self.n_layers as f64
                * (self.params_per_layer() + self.expert_params_per_layer())
            + self.hidden as f64
    }

    /// Forward FLOPs per token for one layer (2·params + attention
    /// matmuls), *excluding* the routed expert FFNs — those scale with
    /// `top_k` (and routing imbalance), priced in the layer profiler.
    pub fn fwd_flops_per_token_layer(&self) -> f64 {
        2.0 * self.params_per_layer()
            + 4.0 * self.seq_len as f64 * self.hidden as f64
    }

    /// Whether the FFN is a routed mixture of experts.
    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Parameters of one layer's whole expert bank (all `n_experts`
    /// routed FFNs: gate/up/down projections each). Zero when dense.
    pub fn expert_params_per_layer(&self) -> f64 {
        3.0 * self.hidden as f64
            * self.expert_intermediate as f64
            * self.n_experts as f64
    }

    /// This shape with a routed expert bank swapped in (the `--experts`
    /// CLI surface): `n_experts` experts of the dense FFN's width, top-2
    /// routing. Dense when `n_experts == 0`.
    pub fn with_experts(&self, n_experts: usize) -> ModelShape {
        ModelShape {
            n_experts,
            top_k: if n_experts == 0 { 0 } else { 2.min(n_experts) },
            expert_intermediate: if n_experts == 0 { 0 } else { self.intermediate },
            ..*self
        }
    }
}

/// Per-chip-type strategy decisions (the HeteroAuto decision variables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupPlan {
    /// Pipeline stages assigned to this chip type (s_pp,i).
    pub s_pp: usize,
    /// Tensor parallel degree (s_tp,i).
    pub s_tp: usize,
    /// Layers assigned to this chip type (l_i), evenly split over its stages.
    pub layers: usize,
    /// Activation recomputation on/off (r_i).
    pub recompute: bool,
}

impl GroupPlan {
    /// Layers each of this group's pipeline stages holds.
    pub fn layers_per_stage(&self) -> usize {
        self.layers.div_ceil(self.s_pp)
    }
}

/// A full strategy for a cluster: one plan per chip group (cluster order)
/// plus the shared data-parallel degree and pipeline schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct Strategy {
    /// Data-parallel degree shared by every chip group.
    pub s_dp: usize,
    /// Expert-parallel degree (s_ep): how many ways each layer's routed
    /// expert bank is sharded. Nested inside data parallelism — every EP
    /// group is `s_ep` of the DP replicas, so `s_ep` divides `s_dp` (and
    /// `n_experts`); exactly 1 for dense models. Drives the profiler's
    /// per-layer all-to-all dispatch/combine terms and the expert slice
    /// of per-chip parameter memory.
    pub s_ep: usize,
    /// Micro-batches per pipeline per iteration (b = B / s_dp).
    pub micro_batches: usize,
    /// Pipeline schedule executed by every stage (1F1B / interleaved /
    /// zero-bubble) — drives the cost model's bubble and memory terms and
    /// the simulator's issue order.
    pub schedule: Schedule,
    /// Collective algorithm of the DP gradient synchronization (flat ring
    /// / tree / recursive halving-doubling / hierarchical, or the
    /// topology-aware `auto` selector) — drives the cost model's and
    /// simulator's `t_update` via [`profile_layer_comm`].
    pub comm_algo: CommAlgo,
    /// Plans in *memory-descending group order* (HeteroPP stage order).
    pub plans: Vec<GroupPlan>,
}

impl Strategy {
    /// Pipeline depth: stages summed over every chip group.
    pub fn total_stages(&self) -> usize {
        self.plans.iter().map(|p| p.s_pp).sum()
    }

    /// Layers assigned across every chip group.
    pub fn total_layers(&self) -> usize {
        self.plans.iter().map(|p| p.layers).sum()
    }
}

/// Cost-model evaluation of a (cluster, strategy) pair.
#[derive(Clone, Debug)]
pub struct Evaluation {
    /// Estimated seconds per iteration (the paper's T).
    pub iteration_seconds: f64,
    /// b·T_comp,i per group.
    pub compute_seconds: Vec<f64>,
    /// T_update,i per group.
    pub update_seconds: Vec<f64>,
    /// Peak memory bytes per chip, per group (worst stage of that group).
    pub peak_memory: Vec<f64>,
    /// Whether every group fits its memory budget.
    pub feasible: bool,
}

/// Fraction of chip memory treated as safely usable (§4.3.2 requirement 3).
pub const MEMORY_SAFETY: f64 = 0.92;

/// Evaluate the §4.3.2 cost model. `groups` must be in memory-descending
/// order and positionally matched with `strategy.plans`. The bubble
/// coefficient and activation residency come from `strategy.schedule`;
/// the DP gradient-sync collective from `strategy.comm_algo`.
///
/// Profiles each group on the fly; hot callers that already hold the
/// per-group [`LayerProfile`]s (HeteroAuto's DFS leaves, the sharding
/// refinement) use [`evaluate_with_profiles`] instead, which is
/// bit-identical given the same profiles.
pub fn evaluate(
    model: &ModelShape,
    groups: &[&ChipGroup],
    strategy: &Strategy,
    micro_tokens: usize,
) -> Evaluation {
    assert_eq!(groups.len(), strategy.plans.len());
    // The closed form has no NIC-policy axis (it models no reshard
    // traffic either — both are simulator ablations): DP sync is
    // priced at the paper-default affine mapping.
    let profiles: Vec<LayerProfile> = groups
        .iter()
        .zip(&strategy.plans)
        .map(|(g, plan)| {
            profile_layer_comm(
                &g.spec, model, plan.s_tp, micro_tokens, strategy.s_dp, strategy.s_ep,
                strategy.comm_algo, crate::topology::NicAssignment::Affinity,
            )
        })
        .collect();
    evaluate_with_profiles(model, groups, strategy, micro_tokens, &profiles)
}

/// [`evaluate`] over pre-computed per-group profiles (positionally matched
/// with `groups`/`strategy.plans`, priced under `strategy.comm_algo` and
/// the affine NIC mapping — exactly what [`evaluate`] computes inline, or
/// what a [`ProfileCache`] returns for those keys).
pub fn evaluate_with_profiles(
    model: &ModelShape,
    groups: &[&ChipGroup],
    strategy: &Strategy,
    micro_tokens: usize,
    profiles: &[LayerProfile],
) -> Evaluation {
    assert_eq!(groups.len(), strategy.plans.len());
    assert_eq!(groups.len(), profiles.len());
    let alpha = strategy.schedule.bubble_coefficient();
    let b = strategy.micro_batches as f64;
    let total_stages = strategy.total_stages();

    let mut compute = Vec::with_capacity(groups.len());
    let mut update = Vec::with_capacity(groups.len());
    let mut peak_mem = Vec::with_capacity(groups.len());
    let mut feasible = true;

    // Stage positions are assigned in group order (memory-descending).
    let mut first_stage = 0usize;
    for ((g, plan), prof) in groups.iter().zip(&strategy.plans).zip(profiles) {
        let lps = plan.layers_per_stage() as f64;
        let mut t_comp = lps
            * (prof.t_fwd + prof.t_bwd + if plan.recompute { prof.t_recompute } else { 0.0 });
        let mut t_up = lps * prof.t_update;

        // Peak memory is attained at this group's *earliest* stage (deepest
        // warm-up queue, Observation #4).
        let mem = stage_memory_bytes(
            &g.spec, model, plan, strategy, first_stage, total_stages, micro_tokens,
            first_stage == 0, first_stage + plan.s_pp == total_stages,
        );
        peak_mem.push(mem.total());
        if mem.total() > g.spec.memory_bytes() * MEMORY_SAFETY {
            feasible = false;
        }
        if mem.offloaded {
            // Synchronous gradient streaming per microbatch + fp32 optimizer
            // shard traffic once per iteration (the Chip-D offload tax).
            t_comp += lps * prof.t_offload_micro;
            t_up += lps * prof.t_offload;
        }
        compute.push(b * t_comp);
        update.push(t_up);
        first_stage += plan.s_pp;
    }

    // T = max_i ( b·T_comp,i + T_update,i + α·Σ_{j≠i} T_comp,j ), where i/j
    // range over pipeline *stages*. Stages of one chip type are uniform, so
    // Σ_{j≠i} T_comp,j = Σ_g s_pp,g·t_g − t_i with t_g the per-stage
    // single-microbatch compute time of group g.
    let stage_sum: f64 = strategy
        .plans
        .iter()
        .enumerate()
        .map(|(g, plan)| plan.s_pp as f64 * compute[g] / b)
        .sum();
    let mut iteration = 0.0f64;
    for g in 0..groups.len() {
        let t_stage = compute[g] / b;
        let t = compute[g] + update[g] + alpha * (stage_sum - t_stage);
        iteration = iteration.max(t);
    }

    Evaluation {
        iteration_seconds: iteration,
        compute_seconds: compute,
        update_seconds: update,
        peak_memory: peak_mem,
        feasible,
    }
}

/// Evaluate the cost model on a serialized [`crate::plan::ExecutionPlan`]
/// — the plan-centric entry point; a free-function alias for
/// [`crate::plan::ExecutionPlan::evaluate`].
pub fn evaluate_plan(plan: &crate::plan::ExecutionPlan) -> Evaluation {
    plan.evaluate()
}

/// Tokens/chip/second (the paper's TGS metric) for an evaluated strategy.
pub fn tgs(cluster: &Cluster, gbs_tokens: usize, iteration_seconds: f64) -> f64 {
    gbs_tokens as f64 / iteration_seconds / cluster.total_chips() as f64
}

/// Rewrite a strategy in place into the uniform-1F1B baseline: equal layer
/// count per stage, recomputation everywhere, and the plain 1F1B schedule
/// (the homogeneous-style configuration the Table 9 ablation and
/// `h2 simulate --uniform` compare against).
///
/// Leftover layers are topped up in whole layers-per-stage increments,
/// always stepping *toward* the exact total (largest step that still fits
/// first), so the baseline never silently simulates more layers than the
/// model has. With wildly mismatched per-group stage counts an exact match
/// can be unreachable (every stage keeps >= 1 layer); the result then stops
/// at the closest reachable total.
pub fn uniform_1f1b(strategy: &mut Strategy, n_layers: usize) {
    strategy.schedule = Schedule::OneF1B;
    let total_stages = strategy.total_stages();
    if total_stages == 0 {
        return;
    }
    let lps = (n_layers / total_stages).max(1);
    for p in strategy.plans.iter_mut() {
        p.layers = lps * p.s_pp;
        p.recompute = true;
    }
    let mut total = strategy.total_layers();
    while total != n_layers {
        let step = if total < n_layers {
            // Add the largest per-group step that doesn't overshoot.
            strategy
                .plans
                .iter()
                .enumerate()
                .filter(|(_, p)| p.s_pp <= n_layers - total)
                .max_by_key(|(_, p)| p.s_pp)
                .map(|(i, p)| (i, p.s_pp as i64))
        } else {
            // Remove the largest step that doesn't undershoot or empty a group.
            strategy
                .plans
                .iter()
                .enumerate()
                .filter(|(_, p)| p.layers > p.s_pp && p.s_pp <= total - n_layers)
                .max_by_key(|(_, p)| p.s_pp)
                .map(|(i, p)| (i, -(p.s_pp as i64)))
        };
        let Some((i, delta)) = step else { break };
        let p = &mut strategy.plans[i];
        p.layers = (p.layers as i64 + delta) as usize;
        total = (total as i64 + delta) as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{homogeneous_baseline, ChipKind};

    #[test]
    fn uniform_1f1b_hits_exact_layer_totals() {
        // Mismatched stage counts that the naive round-robin overshot:
        // s_pp [24, 16] needs lps [2, 3] to land exactly on 96.
        let mut s = Strategy {
            s_ep: 1,
            s_dp: 1,
            micro_batches: 8,
            schedule: Schedule::ZeroBubbleV,
            comm_algo: CommAlgo::Ring,
            plans: vec![
                GroupPlan { s_pp: 24, s_tp: 1, layers: 0, recompute: false },
                GroupPlan { s_pp: 16, s_tp: 1, layers: 0, recompute: false },
            ],
        };
        uniform_1f1b(&mut s, 96);
        assert_eq!(s.total_layers(), 96, "plans {:?}", s.plans);
        assert!(s.plans.iter().all(|p| p.recompute && p.layers % p.s_pp == 0));
        // The baseline is *1F1B* by definition, whatever the input ran.
        assert_eq!(s.schedule, Schedule::OneF1B);

        // The easy homogeneous case stays exactly uniform.
        let mut s = Strategy {
            s_ep: 1,
            s_dp: 1,
            micro_batches: 8,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 1, layers: 0, recompute: false }],
        };
        uniform_1f1b(&mut s, 96);
        assert_eq!(s.plans[0].layers, 96);
    }

    #[test]
    fn table4_shape_is_100b() {
        let p = H2_100B.total_params();
        assert!(p > 95e9 && p < 110e9, "params {p}");
    }

    #[test]
    fn evaluate_homogeneous_a_is_sane() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        // Table 6 row: PP=16, DP=4, TP=4, no recompute.
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128, // 2M tokens / 4096 seq / 4 dp
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
        };
        let eval = evaluate(&H2_100B, &groups, &strategy, 4096);
        assert!(eval.feasible, "peak mem {:?}", eval.peak_memory);
        let tgs = tgs(&exp.cluster, exp.gbs_tokens, eval.iteration_seconds);
        // Table 6: 136.9 TGS. The analytic model should land within ~15%.
        assert!((tgs - 136.9).abs() / 136.9 < 0.15, "TGS {tgs}");
    }

    #[test]
    fn more_microbatches_amortize_bubble() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let mk = |mb| Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: mb,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
        };
        let t_small = evaluate(&H2_100B, &groups, &mk(16), 4096);
        let t_big = evaluate(&H2_100B, &groups, &mk(128), 4096);
        // Throughput per microbatch must improve with more microbatches.
        assert!(t_big.iteration_seconds / 128.0 < t_small.iteration_seconds / 16.0);
    }

    #[test]
    fn schedule_ordering_holds_in_closed_form() {
        // Zero-bubble < interleaved < 1F1B on the same strategy: the bubble
        // term shrinks with the schedule's coefficient.
        let exp = homogeneous_baseline(ChipKind::B);
        let groups = exp.cluster.groups_by_memory_desc();
        let mk = |schedule| Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: true }],
        };
        let t1 = evaluate(&H2_100B, &groups, &mk(Schedule::OneF1B), 4096);
        let ti = evaluate(&H2_100B, &groups,
                          &mk(Schedule::Interleaved { virtual_stages: 2 }), 4096);
        let t0 = evaluate(&H2_100B, &groups, &mk(Schedule::ZeroBubbleV), 4096);
        assert!(t0.iteration_seconds < ti.iteration_seconds,
                "zbv {} vs interleaved {}", t0.iteration_seconds, ti.iteration_seconds);
        assert!(ti.iteration_seconds < t1.iteration_seconds,
                "interleaved {} vs 1f1b {}", ti.iteration_seconds, t1.iteration_seconds);
    }

    #[test]
    fn recompute_costs_time_saves_memory() {
        let exp = homogeneous_baseline(ChipKind::B);
        let groups = exp.cluster.groups_by_memory_desc();
        let mk = |rec| Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: rec }],
        };
        let with = evaluate(&H2_100B, &groups, &mk(true), 4096);
        let without = evaluate(&H2_100B, &groups, &mk(false), 4096);
        // Recompute saves memory...
        assert!(with.peak_memory[0] < without.peak_memory[0]);
        // ...and B-without-recompute is forced into costly gradient offload
        // (Table 6's rationale for recompute on B): recompute is the
        // cheaper escape from the memory wall.
        assert!(with.iteration_seconds < without.iteration_seconds,
                "with {} vs without-offloaded {}", with.iteration_seconds,
                without.iteration_seconds);
    }
}
