//! Per-stage memory model (§4.3.2 requirement 3, Observation #4).
//!
//! Accounts, per chip, for:
//! * bf16 weights + gradients (TP-sharded),
//! * fp32 optimizer states, ZeRO-1-sharded across DP (or offloaded),
//! * activations of the pipeline warm-up queue, schedule-dependent
//!   ([`crate::costmodel::Schedule::activation_residency`]): under 1F1B a
//!   stage at position `p` keeps `min(b, s_pp − p)` micro-batches in
//!   flight — the reason HeteroPP maps large-memory chips to early stages
//!   — interleaving multiplies the residency of later stages, and the
//!   zero-bubble schedule stays within the 1F1B envelope,
//! * embedding/LM-head extras on the first/last stages.
//!
//! The per-layer activation constant (68·tokens·hidden/tp bytes without
//! recomputation, 2·tokens·hidden with) is calibrated so Table 6's "Extra"
//! column is reproduced: A trains bare, B and C cannot fit natively without
//! recomputation, D fits only via CPU offload (see tests).

use crate::hetero::ChipSpec;

use super::{GroupPlan, ModelShape, Strategy, MEMORY_SAFETY};

/// Activation bytes per layer per in-flight microbatch, without recompute.
pub const ACT_BYTES_FACTOR: f64 = 68.0;

/// Bytes per parameter: bf16 weights + bf16 grads.
const WEIGHT_GRAD_BYTES: f64 = 4.0;
/// Bytes per parameter of fp32 optimizer state (m, v, master weights).
const OPTIMIZER_BYTES: f64 = 12.0;

#[derive(Clone, Copy, Debug, Default)]
/// Per-stage memory accounting, bytes per chip.
pub struct MemoryBreakdown {
    /// bf16 weights + gradients, bytes.
    pub weights_grads: f64,
    /// fp32 optimizer states (ZeRO-1 sharded), bytes.
    pub optimizer: f64,
    /// Warm-up-queue activation residency, bytes.
    pub activations: f64,
    /// Embedding / LM-head extras on the first/last stages, bytes.
    pub embed_head: f64,
    /// True if optimizer states had to be offloaded to host memory to fit.
    pub offloaded: bool,
}

impl MemoryBreakdown {
    /// Total bytes per chip across every component.
    pub fn total(&self) -> f64 {
        self.weights_grads + self.optimizer + self.activations + self.embed_head
    }
}

/// Peak memory for the *earliest* (deepest warm-up) stage a group owns.
#[allow(clippy::too_many_arguments)]
pub fn stage_memory_bytes(
    spec: &ChipSpec,
    model: &ModelShape,
    plan: &GroupPlan,
    strategy: &Strategy,
    stage_position: usize,
    total_stages: usize,
    micro_tokens: usize,
    is_first: bool,
    is_last: bool,
) -> MemoryBreakdown {
    let tp = plan.s_tp as f64;
    // The routed expert bank is EP-sharded across `s_ep` of the DP
    // replicas (then TP-sharded like everything else): the memory lever
    // the EP axis exists for. Dense models contribute exactly 0 here.
    let params_stage = plan.layers_per_stage() as f64
        * (model.params_per_layer() + model.expert_params_per_layer() / strategy.s_ep as f64)
        / tp;

    let weights_grads = params_stage * WEIGHT_GRAD_BYTES;
    let mut optimizer = params_stage * OPTIMIZER_BYTES / strategy.s_dp as f64;

    // Schedule-dependent warm-up queue depth at this stage position.
    let in_flight = strategy
        .schedule
        .activation_residency(strategy.micro_batches, total_stages, stage_position);
    let tokens = micro_tokens as f64;
    let act_per_layer = if plan.recompute {
        2.0 * tokens * model.hidden as f64 // stashed stage inputs only
    } else {
        ACT_BYTES_FACTOR * tokens * model.hidden as f64 / tp
    };
    let activations = in_flight * plan.layers_per_stage() as f64 * act_per_layer;

    let embed_params = model.vocab as f64 * model.hidden as f64 / tp
        * (is_first as u32 + is_last as u32) as f64;
    // Transient fp32 logits + softmax workspace for one microbatch.
    let logits = if is_last { tokens * model.vocab as f64 * 6.0 / tp } else { 0.0 };
    let embed_head =
        embed_params * (WEIGHT_GRAD_BYTES + OPTIMIZER_BYTES / strategy.s_dp as f64) + logits;

    let mut out = MemoryBreakdown {
        weights_grads,
        optimizer,
        activations,
        embed_head,
        offloaded: false,
    };

    // If over budget, spill optimizer states and gradient accumulation
    // buffers to host memory (the paper's Chip-D CPU-offload fallback,
    // ZeRO-Offload style) and retry; bf16 weights stay on device.
    if out.total() > spec.memory_bytes() * MEMORY_SAFETY {
        optimizer = 0.0;
        let retry = MemoryBreakdown {
            weights_grads: params_stage * 2.0,
            optimizer,
            embed_head: embed_params * 2.0 + logits,
            offloaded: true,
            ..out
        };
        if retry.total() <= spec.memory_bytes() * MEMORY_SAFETY {
            out = retry;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{GroupPlan, Strategy, H2_100B};
    use crate::hetero::{spec, ChipKind};

    fn eval(kind: ChipKind, pp: usize, tp: usize, dp: usize, recompute: bool) -> MemoryBreakdown {
        let plan = GroupPlan { s_pp: pp, s_tp: tp, layers: 96, recompute };
        let strategy = Strategy {
            s_ep: 1,
            s_dp: dp,
            micro_batches: 2 * 1024 * 1024 / 4096 / dp,
            schedule: crate::costmodel::Schedule::OneF1B,
            comm_algo: crate::comm::CommAlgo::Ring,
            plans: vec![plan],
        };
        stage_memory_bytes(&spec(kind), &H2_100B, &plan, &strategy, 0, pp, 4096, true, false)
    }

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    #[test]
    fn table6_chip_a_fits_without_recompute() {
        let m = eval(ChipKind::A, 16, 4, 4, false);
        assert!(!m.offloaded);
        assert!(m.total() < 96.0 * GIB * MEMORY_SAFETY, "A {}", m.total() / GIB);
    }

    #[test]
    fn table6_chip_b_needs_recompute() {
        // Without recompute B cannot fit natively (only via costly offload);
        // with recompute it fits cleanly — matching Table 6's Extra column.
        let without = eval(ChipKind::B, 16, 4, 4, false);
        assert!(without.offloaded, "B w/o recompute should be forced to offload: {} GiB",
                without.total() / GIB);
        let with = eval(ChipKind::B, 16, 4, 4, true);
        assert!(!with.offloaded);
        assert!(with.total() < 64.0 * GIB * MEMORY_SAFETY, "B {}", with.total() / GIB);
    }

    #[test]
    fn table6_chip_c_needs_recompute() {
        let without = eval(ChipKind::C, 32, 4, 2, false);
        assert!(without.total() > 32.0 * GIB * MEMORY_SAFETY);
        let with = eval(ChipKind::C, 32, 4, 2, true);
        assert!(with.total() < 32.0 * GIB * MEMORY_SAFETY, "C {}", with.total() / GIB);
    }

    #[test]
    fn table6_chip_d_needs_offload() {
        // D: PP=8, TP=8, DP=4, no recompute -> fits only by offloading.
        let m = eval(ChipKind::D, 8, 8, 4, false);
        assert!(m.offloaded, "D should offload: {} GiB", m.total() / GIB);
        assert!(m.total() < 32.0 * GIB * MEMORY_SAFETY);
    }

    #[test]
    fn later_stages_use_less_activation_memory() {
        let plan = GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false };
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: crate::costmodel::Schedule::OneF1B,
            comm_algo: crate::comm::CommAlgo::Ring,
            plans: vec![plan],
        };
        let early = stage_memory_bytes(&spec(ChipKind::A), &H2_100B, &plan, &strategy,
                                       0, 16, 4096, false, false);
        let late = stage_memory_bytes(&spec(ChipKind::A), &H2_100B, &plan, &strategy,
                                      15, 16, 4096, false, false);
        assert!(late.activations < early.activations / 4.0);
    }

    #[test]
    fn interleaving_multiplies_late_stage_activation_residency() {
        let plan = GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false };
        let mk = |schedule| Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule,
            comm_algo: crate::comm::CommAlgo::Ring,
            plans: vec![plan],
        };
        let s1 = mk(crate::costmodel::Schedule::OneF1B);
        let si = mk(crate::costmodel::Schedule::Interleaved { virtual_stages: 2 });
        let late_1f1b = stage_memory_bytes(&spec(ChipKind::A), &H2_100B, &plan, &s1,
                                           12, 16, 4096, false, false);
        let late_il = stage_memory_bytes(&spec(ChipKind::A), &H2_100B, &plan, &si,
                                         12, 16, 4096, false, false);
        assert!(late_il.activations > late_1f1b.activations,
                "interleaved {} <= 1f1b {}", late_il.activations, late_1f1b.activations);
    }

    #[test]
    fn recompute_shrinks_activations() {
        let with = eval(ChipKind::A, 16, 4, 4, true);
        let without = eval(ChipKind::A, 16, 4, 4, false);
        assert!(with.activations < without.activations / 3.0);
    }

    #[test]
    fn ep_shards_expert_parameter_memory() {
        use crate::costmodel::H2_MOE;
        let plan = GroupPlan { s_pp: 15, s_tp: 4, layers: 60, recompute: true };
        let mk = |s_ep| Strategy {
            s_ep,
            s_dp: 8,
            micro_batches: 16,
            schedule: crate::costmodel::Schedule::OneF1B,
            comm_algo: crate::comm::CommAlgo::Ring,
            plans: vec![plan],
        };
        let at = |s: &Strategy| {
            stage_memory_bytes(&spec(ChipKind::A), &H2_MOE, &plan, s, 0, 15, 4096, true, false)
        };
        let ep1 = at(&mk(1));
        let ep8 = at(&mk(8));
        // The 32-expert bank dominates EP=1 parameter memory; EP=8 keeps
        // 1/8th of it per replica and must shed the rest.
        assert!(
            ep8.weights_grads < ep1.weights_grads / 2.0,
            "ep8 {} !<< ep1 {}",
            ep8.weights_grads,
            ep1.weights_grads
        );
        // Activations are routing-invariant: EP moves parameters only.
        assert_eq!(ep8.activations, ep1.activations);
    }
}
