//! The plan-centric API: a serializable [`ExecutionPlan`] is the single
//! artifact flowing through search → simulate → train.
//!
//! `HeteroAuto` emits one ([`crate::auto::SearchResult::into_plan`]), the
//! HeteroPP simulator and the real training coordinator consume one
//! ([`ExecutionPlan::simulate`], [`crate::coordinator::train_plan`]), and
//! the CLI persists one (`h2 search --emit-plan plan.json`, then
//! `h2 simulate|train --plan plan.json`). The JSON form is self-contained:
//! custom chips referenced by the plan are embedded and re-registered on
//! load, so a plan file moves between processes and machines.
//!
//! Construction goes through [`PlanBuilder`]; every structural invariant
//! the cost model, simulator and coordinator rely on is checked by
//! [`ExecutionPlan::validate`], which reports *all* violations as typed
//! [`PlanError`]s.

mod builder;
mod error;

pub use builder::PlanBuilder;
pub use error::{render_errors, PlanError};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::{CommAlgo, CommMode};
use crate::coordinator::{StagePlan, TrainConfig};
use crate::costmodel::{evaluate, tgs, Evaluation, GroupPlan, ModelShape, Schedule, Strategy};
use crate::elastic::FaultPlan;
use crate::hetero::{self, ChipGroup, ChipKind, Cluster, CustomChipDef, IntraNodeLink};
use crate::precision::MRE_THRESHOLD;
use crate::sim::{simulate_iteration, ReshardStrategy, SimOptions, SimResult};
use crate::topology::NicAssignment;
use crate::util::json::{self, Value};

/// Plan-file schema version. Version 5 added the expert-parallel axis:
/// `s_ep` inside `strategy` (the expert-parallel degree; a missing field —
/// every v1–v4 file — loads as 1) and the MoE shape fields inside `model`
/// (`n_experts`, `top_k`, `expert_intermediate`; missing fields load as 0,
/// i.e. dense).
/// Version 4 added the elastic-training fields:
/// `plan_epoch` (how many times the plan has been re-planned; a missing
/// field — every v1–v3 file — loads as 0) and the optional `fault_plan`
/// section (a seeded fault-injection scenario, absent unless set).
/// Version 3 added the `comm_algo` token inside `strategy` (the
/// DP-collective algorithm of the DiComm engine); files without one —
/// every v1/v2 file — load as `ring`, the previously hardwired collective.
/// Version 2 replaced the top-level `alpha` bubble coefficient with a
/// `schedule` token inside `strategy`; version-1 files still load, their
/// `alpha` mapped through [`Schedule::from_alpha`] (see
/// `docs/plan-format.md` for the full compatibility rules).
pub const PLAN_VERSION: u64 = 5;

/// Numeric-precision policy carried by a plan into real training runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionPolicy {
    /// Inject per-chip vendor-stack operator noise (the Fig 5 model).
    pub perturb: bool,
    /// Model-level alignment criterion (MRE of the loss curve).
    pub mre_threshold: f64,
}

impl Default for PrecisionPolicy {
    fn default() -> Self {
        PrecisionPolicy { perturb: false, mre_threshold: MRE_THRESHOLD }
    }
}

/// The real-training section of a plan: which AOT artifact set to run and
/// how to shard it. Comm mode, NIC assignment, overlap and precision come
/// from the owning plan.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainSpec {
    /// Artifact model name (e.g. `h2_tiny`), resolved via the manifest.
    pub model: String,
    /// Pipeline stages in order (first → last).
    pub stages: Vec<StagePlan>,
    /// Data-parallel replica count.
    pub dp: usize,
    /// Micro-batches per pipeline per step.
    pub micro_batches: usize,
    /// Training steps to run.
    pub steps: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Parameter-init and data seed.
    pub seed: u64,
    /// Print a loss line every N steps (0 = silent).
    pub log_every: usize,
}

/// A complete, serializable description of one training execution:
/// cluster + model shape + parallel strategy + communication configuration.
///
/// `stage_groups` are in memory-descending HeteroPP stage order and are
/// positionally matched with `strategy.plans` (they may be the two-stage
/// search's pseudo-subgroups, hence kept separate from `cluster.groups`).
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutionPlan {
    /// Schema version of the serialized form ([`PLAN_VERSION`] after load,
    /// whatever the file carried — loading migrates in memory).
    pub version: u64,
    /// Human-readable plan name (shows up in CLI output).
    pub name: String,
    /// Transformer shape the strategy was searched for.
    pub model: ModelShape,
    /// The physical cluster the plan was built for.
    pub cluster: Cluster,
    /// Stage-ordered groups matched 1:1 with `strategy.plans`.
    pub stage_groups: Vec<ChipGroup>,
    /// The parallel strategy, including the pipeline [`Schedule`].
    pub strategy: Strategy,
    /// Global batch size in tokens.
    pub gbs_tokens: usize,
    /// Tokens per micro-batch (the paper pins micro batch size to 1 sequence).
    pub micro_tokens: usize,
    /// Cross-chip communication strategy.
    pub comm: CommMode,
    /// Inter-stage activation resharding strategy.
    pub reshard: ReshardStrategy,
    /// NIC selection policy (§5 affinity model).
    pub nic_assignment: NicAssignment,
    /// Fine-grained P2P/compute overlap enabled.
    pub fine_overlap: bool,
    /// Numeric-precision policy for real training runs.
    pub precision: PrecisionPolicy,
    /// Optional real-training section (`h2 train --plan`).
    pub train: Option<TrainSpec>,
    /// How many times this plan has been re-planned by the elastic loop
    /// (0 for a freshly searched plan; `auto::replan` increments it).
    pub plan_epoch: u64,
    /// Optional seeded fault-injection scenario replayed by the simulator
    /// and the virtual coordinator (`h2 train --virtual --faults`).
    pub fault_plan: Option<FaultPlan>,
}

impl ExecutionPlan {
    /// Stage-ordered group references, the shape the cost model/simulator eat.
    pub fn group_refs(&self) -> Vec<&ChipGroup> {
        self.stage_groups.iter().collect()
    }

    /// The pipeline schedule this plan executes (carried by the strategy).
    pub fn schedule(&self) -> Schedule {
        self.strategy.schedule
    }

    /// Simulation options implied by the plan's communication section.
    pub fn sim_options(&self) -> SimOptions {
        SimOptions {
            comm: self.comm,
            reshard: self.reshard,
            nic_assignment: self.nic_assignment,
            fine_overlap: self.fine_overlap,
        }
    }

    /// Evaluate the §4.3.2 closed-form cost model on this plan.
    pub fn evaluate(&self) -> Evaluation {
        evaluate(&self.model, &self.group_refs(), &self.strategy, self.micro_tokens)
    }

    /// Run the discrete-event HeteroPP simulator on this plan.
    pub fn simulate(&self) -> SimResult {
        simulate_iteration(
            &self.model,
            &self.group_refs(),
            &self.strategy,
            self.micro_tokens,
            &self.sim_options(),
        )
    }

    /// Tokens/chip/second over this plan's cluster for a given iteration time.
    pub fn tgs(&self, iteration_seconds: f64) -> f64 {
        tgs(&self.cluster, self.gbs_tokens, iteration_seconds)
    }

    /// Lower the plan into a [`TrainConfig`] for the real coordinator —
    /// the plan's `strategy.schedule` and `strategy.comm_algo` travel
    /// with it, so the coordinator executes what the search priced and
    /// the simulator replayed. Errors if the plan has no `train` section.
    pub fn train_config(&self) -> Result<TrainConfig> {
        let t = self
            .train
            .as_ref()
            .ok_or_else(|| anyhow!("plan `{}` has no train section", self.name))?;
        Ok(TrainConfig {
            model: t.model.clone(),
            stages: t.stages.clone(),
            dp: t.dp,
            micro_batches: t.micro_batches,
            steps: t.steps,
            lr: t.lr,
            seed: t.seed,
            schedule: self.strategy.schedule,
            comm_algo: self.strategy.comm_algo,
            comm: self.comm,
            nic_assignment: self.nic_assignment,
            fine_overlap: self.fine_overlap,
            perturb: self.precision.perturb,
            log_every: t.log_every,
        })
    }

    // -- validation --------------------------------------------------------

    /// Check every structural invariant; collects all violations.
    pub fn validate(&self) -> std::result::Result<(), Vec<PlanError>> {
        let mut errs = Vec::new();
        if self.stage_groups.is_empty() {
            errs.push(PlanError::EmptyGroups);
        }
        if self.stage_groups.len() != self.strategy.plans.len() {
            errs.push(PlanError::GroupsMismatch {
                groups: self.stage_groups.len(),
                plans: self.strategy.plans.len(),
            });
        }
        if self.micro_tokens == 0 {
            errs.push(PlanError::ZeroMicroTokens);
        }
        if let Schedule::Interleaved { virtual_stages } = self.strategy.schedule {
            if virtual_stages < 2 {
                errs.push(PlanError::VirtualStagesInvalid { virtual_stages });
            } else {
                for (i, p) in self.strategy.plans.iter().enumerate() {
                    // Only meaningful once the layers split over the stages
                    // at all (LayersNotUniform covers the rest).
                    if p.s_pp > 0
                        && p.layers % p.s_pp == 0
                        && p.layers_per_stage() % virtual_stages != 0
                    {
                        errs.push(PlanError::LayersNotVirtualizable {
                            group: i,
                            layers_per_stage: p.layers_per_stage(),
                            virtual_stages,
                        });
                    }
                }
            }
        }
        if self.strategy.s_dp == 0 {
            errs.push(PlanError::ZeroDp);
        }
        if self.strategy.micro_batches == 0 {
            errs.push(PlanError::ZeroMicroBatches);
        }
        // Expert-parallel axis: EP groups are carved out of the DP
        // replicas and shard the expert bank evenly; dense plans are
        // pinned to s_ep == 1.
        let s_ep = self.strategy.s_ep;
        if s_ep == 0 {
            errs.push(PlanError::ZeroEp);
        } else {
            if self.strategy.s_dp > 0 && self.strategy.s_dp % s_ep != 0 {
                errs.push(PlanError::EpNotInDp { s_ep, s_dp: self.strategy.s_dp });
            }
            if self.model.is_moe() {
                if self.model.n_experts % s_ep != 0 {
                    errs.push(PlanError::EpNotInExperts {
                        s_ep,
                        n_experts: self.model.n_experts,
                    });
                }
            } else if s_ep > 1 {
                errs.push(PlanError::EpWithoutExperts { s_ep });
            }
        }
        if self.model.is_moe()
            && (self.model.top_k == 0
                || self.model.top_k > self.model.n_experts
                || self.model.expert_intermediate == 0)
        {
            errs.push(PlanError::MoeShapeInvalid {
                n_experts: self.model.n_experts,
                top_k: self.model.top_k,
                expert_intermediate: self.model.expert_intermediate,
            });
        }
        if self.micro_tokens > 0 {
            let sequences = self.gbs_tokens / self.micro_tokens;
            if self.gbs_tokens % self.micro_tokens != 0 {
                errs.push(PlanError::TokensNotWholeSequences {
                    gbs_tokens: self.gbs_tokens,
                    micro_tokens: self.micro_tokens,
                });
            }
            if sequences == 0 {
                errs.push(PlanError::BatchBelowOneSequence {
                    gbs_tokens: self.gbs_tokens,
                    micro_tokens: self.micro_tokens,
                });
            } else if self.strategy.s_dp > 0
                && self.strategy.s_dp * self.strategy.micro_batches != sequences
            {
                errs.push(PlanError::BatchMismatch {
                    sequences,
                    s_dp: self.strategy.s_dp,
                    micro_batches: self.strategy.micro_batches,
                });
            }
        }
        // stage_groups must repartition the physical cluster: per chip kind
        // the stage-ordered groups account for exactly the cluster's chips
        // (they may be pseudo-subgroups, so totals are compared per kind).
        let mut tally: std::collections::BTreeMap<ChipKind, (usize, usize)> =
            std::collections::BTreeMap::new();
        for g in &self.cluster.groups {
            tally.entry(g.spec.kind).or_insert((0, 0)).0 += g.n_chips;
        }
        for g in &self.stage_groups {
            tally.entry(g.spec.kind).or_insert((0, 0)).1 += g.n_chips;
        }
        for (kind, (cluster, stages)) in tally {
            if cluster != stages {
                errs.push(PlanError::ClusterMismatch {
                    chip: kind.name().to_string(),
                    cluster,
                    stages,
                });
            }
        }
        for (i, (g, p)) in self.stage_groups.iter().zip(&self.strategy.plans).enumerate() {
            if g.n_chips % g.spec.chips_per_node != 0 {
                errs.push(PlanError::PartialNode {
                    group: i,
                    chips: g.n_chips,
                    chips_per_node: g.spec.chips_per_node,
                });
            }
            if !p.s_tp.is_power_of_two() {
                errs.push(PlanError::TpNotPowerOfTwo { group: i, s_tp: p.s_tp });
            }
            if p.s_tp > g.spec.tp_max() {
                errs.push(PlanError::TpExceedsMax {
                    group: i,
                    s_tp: p.s_tp,
                    tp_max: g.spec.tp_max(),
                });
            }
            if self.strategy.s_dp > 0 && p.s_pp * p.s_tp * self.strategy.s_dp != g.n_chips {
                errs.push(PlanError::ChipAccounting {
                    group: i,
                    chips: g.n_chips,
                    s_pp: p.s_pp,
                    s_tp: p.s_tp,
                    s_dp: self.strategy.s_dp,
                });
            }
            if p.layers == 0 {
                errs.push(PlanError::ZeroLayers { group: i });
            } else if p.s_pp == 0 || p.layers % p.s_pp != 0 {
                errs.push(PlanError::LayersNotUniform {
                    group: i,
                    layers: p.layers,
                    s_pp: p.s_pp,
                });
            }
        }
        let assigned = self.strategy.total_layers();
        if assigned != self.model.n_layers {
            errs.push(PlanError::LayersMismatch { assigned, model: self.model.n_layers });
        }
        if let Some(fp) = &self.fault_plan {
            let s_n: usize = self.strategy.plans.iter().map(|p| p.s_pp).sum();
            if let Err(e) = fp.validate(s_n) {
                errs.push(PlanError::FaultPlanInvalid { detail: e.to_string() });
            }
        }
        if let Some(t) = &self.train {
            if t.stages.is_empty() || t.dp == 0 || t.micro_batches == 0 {
                errs.push(PlanError::TrainEmpty);
            }
            let n = t.stages.len();
            for (i, sp) in t.stages.iter().enumerate() {
                let expected =
                    if i == 0 { "first" } else if i == n - 1 { "last" } else { "mid" };
                if !sp.prefix.starts_with(expected) {
                    errs.push(PlanError::TrainStageRole {
                        index: i,
                        prefix: sp.prefix.clone(),
                        expected,
                    });
                }
            }
        }
        if errs.is_empty() { Ok(()) } else { Err(errs) }
    }

    // -- serialization -----------------------------------------------------

    /// Serialize to a self-contained JSON value (embeds custom chip defs).
    pub fn to_json(&self) -> Value {
        let mut custom: Vec<CustomChipDef> = Vec::new();
        let mut note = |def: Option<CustomChipDef>| {
            if let Some(def) = def {
                if !custom.iter().any(|d| d.name == def.name) {
                    custom.push(def);
                }
            }
        };
        // Groups carry a snapshotted ChipSpec — embed *that*, not the live
        // registry state, so the file reflects what the plan computes with.
        // Train stages hold only a ChipKind; for a chip that appears in no
        // group there is no snapshot anywhere in the plan, so those fall
        // back to the registry's current definition (groups win the dedup).
        for g in self.cluster.groups.iter().chain(&self.stage_groups) {
            if g.spec.kind.is_custom() {
                note(Some(hetero::def_from_spec(g.spec.kind.name(), &g.spec)));
            }
        }
        if let Some(t) = &self.train {
            for s in &t.stages {
                note(hetero::custom_def(s.chip));
            }
        }

        let mut fields = vec![
            ("version", json::num(self.version as f64)),
            ("name", json::s(&self.name)),
            ("model", model_to_json(&self.model)),
            ("cluster", cluster_to_json(&self.cluster)),
            ("stage_groups", json::arr(self.stage_groups.iter().map(group_to_json).collect())),
            ("strategy", strategy_to_json(&self.strategy)),
            ("gbs_tokens", json::num(self.gbs_tokens as f64)),
            ("micro_tokens", json::num(self.micro_tokens as f64)),
            ("comm", json::s(self.comm.token())),
            ("reshard", json::s(self.reshard.token())),
            ("nic_assignment", json::s(self.nic_assignment.token())),
            ("fine_overlap", Value::Bool(self.fine_overlap)),
            ("plan_epoch", json::num(self.plan_epoch as f64)),
            (
                "precision",
                json::obj(vec![
                    ("perturb", Value::Bool(self.precision.perturb)),
                    ("mre_threshold", json::num(self.precision.mre_threshold)),
                ]),
            ),
        ];
        if !custom.is_empty() {
            fields.push(("chips", json::arr(custom.iter().map(chip_def_to_json).collect())));
        }
        if let Some(t) = &self.train {
            fields.push(("train", train_to_json(t)));
        }
        if let Some(fp) = &self.fault_plan {
            fields.push(("fault_plan", fp.to_json()));
        }
        json::obj(fields)
    }

    /// Pretty-printed JSON text (what plan files hold on disk).
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Deserialize from a JSON value, registering any embedded custom chips
    /// first so the plan file is self-contained. Version-1 files (scalar
    /// `alpha` instead of a `schedule` token) are migrated in memory via
    /// [`Schedule::from_alpha`]; the returned plan always carries
    /// [`PLAN_VERSION`].
    pub fn from_json(v: &Value) -> Result<ExecutionPlan> {
        // Reject unsupported versions *before* touching the process-wide
        // chip registry, so a version-rejected file leaves no side effects.
        // (Embedded chips must register before groups parse — group parsing
        // resolves chip names through the registry — so a file that fails on
        // a *later* field does leave its chips registered; re-loading a
        // corrected file re-registers them idempotently.)
        let version = v.get("version")?.u64()?;
        if version > PLAN_VERSION {
            bail!("plan version {version} is newer than supported {PLAN_VERSION}");
        }
        if let Some(chips) = v.opt("chips") {
            for c in chips.arr().context("parsing `chips`")? {
                let def = chip_def_from_json(c)?;
                hetero::register_custom(&def)?;
            }
        }
        let precision = match v.opt("precision") {
            Some(p) => PrecisionPolicy {
                perturb: p.get("perturb")?.bool()?,
                mre_threshold: p.get("mre_threshold")?.num()?,
            },
            None => PrecisionPolicy::default(),
        };
        // Version 1 carried the schedule as a top-level scalar `alpha`;
        // keep v1's validation (alpha in [0, inf)) so a corrupt file is
        // still rejected rather than silently mapped to some schedule.
        let legacy_schedule = if version < 2 {
            let alpha = v.get("alpha")?.num()?;
            let alpha_valid = alpha >= 0.0 && alpha.is_finite();
            if !alpha_valid {
                bail!("version-1 plan has alpha {alpha} outside [0, inf)");
            }
            Some(Schedule::from_alpha(alpha))
        } else {
            None
        };
        let mut strategy = strategy_from_json(v.get("strategy")?, legacy_schedule)
            .context("parsing `strategy`")?;
        // A v1 alpha in (0.25, 0.75) maps to interleaving, which carries a
        // structural constraint v1 never had (virtual stages must chunk
        // every stage's layers). A v1 file whose layer layout cannot chunk
        // was nevertheless valid under v1 — fall back to 1F1B (what v1's
        // simulator actually executed) instead of rejecting it.
        if legacy_schedule.is_some() {
            if let Schedule::Interleaved { virtual_stages } = strategy.schedule {
                let chunks = strategy.plans.iter().all(|p| {
                    p.s_pp > 0
                        && p.layers % p.s_pp == 0
                        && p.layers_per_stage() % virtual_stages == 0
                });
                if !chunks {
                    strategy.schedule = Schedule::OneF1B;
                }
            }
        }
        Ok(ExecutionPlan {
            version: PLAN_VERSION,
            name: v.get("name")?.str()?.to_string(),
            model: model_from_json(v.get("model")?).context("parsing `model`")?,
            cluster: cluster_from_json(v.get("cluster")?).context("parsing `cluster`")?,
            stage_groups: v
                .get("stage_groups")?
                .arr()?
                .iter()
                .map(group_from_json)
                .collect::<Result<Vec<_>>>()
                .context("parsing `stage_groups`")?,
            strategy,
            gbs_tokens: v.get("gbs_tokens")?.usize()?,
            micro_tokens: v.get("micro_tokens")?.usize()?,
            comm: parse_token(v.get("comm")?, "comm", CommMode::parse)?,
            reshard: parse_token(v.get("reshard")?, "reshard", ReshardStrategy::parse)?,
            nic_assignment: parse_token(
                v.get("nic_assignment")?,
                "nic_assignment",
                NicAssignment::parse,
            )?,
            fine_overlap: v.get("fine_overlap")?.bool()?,
            precision,
            train: v.opt("train").map(train_from_json).transpose().context("parsing `train`")?,
            // v4 elastic fields: every pre-v4 file is a freshly searched
            // plan (epoch 0) with no fault scenario.
            plan_epoch: match v.opt("plan_epoch") {
                Some(x) => x.u64()?,
                None => 0,
            },
            fault_plan: v
                .opt("fault_plan")
                .map(FaultPlan::from_json)
                .transpose()
                .context("parsing `fault_plan`")?,
        })
    }

    /// Parse a plan from JSON text (see [`ExecutionPlan::from_json`]).
    pub fn from_json_str(text: &str) -> Result<ExecutionPlan> {
        ExecutionPlan::from_json(&Value::parse(text)?)
    }

    /// Write the plan to a JSON file.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing plan to {path}"))
    }

    /// Load and validate a plan from a JSON file.
    pub fn load(path: &str) -> Result<ExecutionPlan> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let plan = ExecutionPlan::from_json_str(&text)
            .with_context(|| format!("parsing plan {path}"))?;
        if let Err(errs) = plan.validate() {
            bail!("plan {path} is invalid:\n{}", render_errors(&errs));
        }
        Ok(plan)
    }
}

/// Parse a canonical token (comm mode, reshard strategy, NIC assignment)
/// with a path-aware error — shared with the config front-end.
pub(crate) fn parse_token<T>(
    v: &Value,
    key: &str,
    parse: impl Fn(&str) -> Option<T>,
) -> Result<T> {
    let s = v.str()?;
    parse(s).ok_or_else(|| anyhow!("bad `{key}` token `{s}`"))
}

/// Parse a chip name (built-in or registered custom) — shared with the
/// config front-end.
pub(crate) fn parse_kind(v: &Value) -> Result<ChipKind> {
    let s = v.str()?;
    ChipKind::parse(s).ok_or_else(|| anyhow!("unknown chip `{s}`"))
}

fn model_to_json(m: &ModelShape) -> Value {
    json::obj(vec![
        ("n_layers", json::num(m.n_layers as f64)),
        ("hidden", json::num(m.hidden as f64)),
        ("n_heads", json::num(m.n_heads as f64)),
        ("n_kv_heads", json::num(m.n_kv_heads as f64)),
        ("intermediate", json::num(m.intermediate as f64)),
        ("vocab", json::num(m.vocab as f64)),
        ("seq_len", json::num(m.seq_len as f64)),
        ("n_experts", json::num(m.n_experts as f64)),
        ("top_k", json::num(m.top_k as f64)),
        ("expert_intermediate", json::num(m.expert_intermediate as f64)),
    ])
}

fn model_from_json(v: &Value) -> Result<ModelShape> {
    // The MoE shape fields arrived in v5; files older than that are all
    // dense, which is exactly what the zero defaults mean.
    let moe_field = |key: &str| -> Result<usize> {
        match v.opt(key) {
            Some(n) => n.usize(),
            None => Ok(0),
        }
    };
    Ok(ModelShape {
        n_layers: v.get("n_layers")?.usize()?,
        hidden: v.get("hidden")?.usize()?,
        n_heads: v.get("n_heads")?.usize()?,
        n_kv_heads: v.get("n_kv_heads")?.usize()?,
        intermediate: v.get("intermediate")?.usize()?,
        vocab: v.get("vocab")?.usize()?,
        seq_len: v.get("seq_len")?.usize()?,
        n_experts: moe_field("n_experts")?,
        top_k: moe_field("top_k")?,
        expert_intermediate: moe_field("expert_intermediate")?,
    })
}

fn group_to_json(g: &ChipGroup) -> Value {
    json::obj(vec![
        ("chip", json::s(g.spec.kind.name())),
        ("chips", json::num(g.n_chips as f64)),
    ])
}

fn group_from_json(v: &Value) -> Result<ChipGroup> {
    ChipGroup::try_new(parse_kind(v.get("chip")?)?, v.get("chips")?.usize()?)
}

fn cluster_to_json(c: &Cluster) -> Value {
    json::obj(vec![
        ("name", json::s(&c.name)),
        ("groups", json::arr(c.groups.iter().map(group_to_json).collect())),
    ])
}

fn cluster_from_json(v: &Value) -> Result<Cluster> {
    Ok(Cluster {
        name: v.get("name")?.str()?.to_string(),
        groups: v
            .get("groups")?
            .arr()?
            .iter()
            .map(group_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

fn strategy_to_json(s: &Strategy) -> Value {
    json::obj(vec![
        ("s_ep", json::num(s.s_ep as f64)),
        ("s_dp", json::num(s.s_dp as f64)),
        ("micro_batches", json::num(s.micro_batches as f64)),
        ("schedule", json::s(&s.schedule.token())),
        ("comm_algo", json::s(s.comm_algo.token())),
        (
            "plans",
            json::arr(
                s.plans
                    .iter()
                    .map(|p| {
                        json::obj(vec![
                            ("s_pp", json::num(p.s_pp as f64)),
                            ("s_tp", json::num(p.s_tp as f64)),
                            ("layers", json::num(p.layers as f64)),
                            ("recompute", Value::Bool(p.recompute)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse a strategy object. `legacy_schedule` is the version-1 migration
/// path (schedule derived from the file's top-level `alpha`); version-2
/// strategies carry their own `schedule` token.
fn strategy_from_json(v: &Value, legacy_schedule: Option<Schedule>) -> Result<Strategy> {
    let mut plans = Vec::new();
    for p in v.get("plans")?.arr()? {
        plans.push(GroupPlan {
            s_pp: p.get("s_pp")?.usize()?,
            s_tp: p.get("s_tp")?.usize()?,
            layers: p.get("layers")?.usize()?,
            recompute: p.get("recompute")?.bool()?,
        });
    }
    let schedule = match legacy_schedule {
        Some(s) => s,
        None => parse_token(v.get("schedule")?, "schedule", Schedule::parse)?,
    };
    // Files older than v3 predate the collective engine: they executed the
    // flat ring, so that is what a missing token migrates to.
    let comm_algo = match v.opt("comm_algo") {
        Some(tok) => parse_token(tok, "comm_algo", CommAlgo::parse)?,
        None => CommAlgo::Ring,
    };
    // Files older than v5 predate the expert-parallel axis: they are all
    // dense plans, i.e. s_ep == 1.
    let s_ep = match v.opt("s_ep") {
        Some(n) => n.usize()?,
        None => 1,
    };
    Ok(Strategy {
        s_ep,
        s_dp: v.get("s_dp")?.usize()?,
        micro_batches: v.get("micro_batches")?.usize()?,
        schedule,
        comm_algo,
        plans,
    })
}

fn link_to_json(link: &IntraNodeLink) -> Value {
    match *link {
        IntraNodeLink::Uniform { gbps } => json::obj(vec![
            ("type", json::s("uniform")),
            ("gbps", json::num(gbps)),
        ]),
        IntraNodeLink::NumaSplit { local_gbps, cross_gbps, island } => json::obj(vec![
            ("type", json::s("numa")),
            ("local_gbps", json::num(local_gbps)),
            ("cross_gbps", json::num(cross_gbps)),
            ("island", json::num(island as f64)),
        ]),
        IntraNodeLink::PcieSwitch { local_gbps, cross_gbps, group } => json::obj(vec![
            ("type", json::s("pcie")),
            ("local_gbps", json::num(local_gbps)),
            ("cross_gbps", json::num(cross_gbps)),
            ("group", json::num(group as f64)),
        ]),
    }
}

fn link_from_json(v: &Value) -> Result<IntraNodeLink> {
    match v.get("type")?.str()? {
        "uniform" => Ok(IntraNodeLink::Uniform { gbps: v.get("gbps")?.num()? }),
        "numa" => Ok(IntraNodeLink::NumaSplit {
            local_gbps: v.get("local_gbps")?.num()?,
            cross_gbps: v.get("cross_gbps")?.num()?,
            island: v.get("island")?.usize()?,
        }),
        "pcie" => Ok(IntraNodeLink::PcieSwitch {
            local_gbps: v.get("local_gbps")?.num()?,
            cross_gbps: v.get("cross_gbps")?.num()?,
            group: v.get("group")?.usize()?,
        }),
        other => bail!("unknown intra-node link type `{other}`"),
    }
}

/// Serialize a custom chip definition (the config-file `chips` entry shape).
pub fn chip_def_to_json(def: &CustomChipDef) -> Value {
    json::obj(vec![
        ("name", json::s(&def.name)),
        ("fp16_tflops", json::num(def.fp16_tflops)),
        ("memory_gib", json::num(def.memory_gib)),
        ("chips_per_node", json::num(def.chips_per_node as f64)),
        ("intra_node", link_to_json(&def.intra_node)),
        ("nics_per_node", json::num(def.nics_per_node as f64)),
        ("nic_gbps", json::num(def.nic_gbps)),
        ("mfu", json::num(def.mfu)),
        ("op_noise", json::num(def.op_noise)),
        ("pcie_to_nic_gbps", json::num(def.pcie_to_nic_gbps)),
        ("cross_switch_share", json::num(def.cross_switch_share)),
    ])
}

const CHIP_DEF_KEYS: [&str; 11] = [
    "name", "fp16_tflops", "memory_gib", "chips_per_node", "intra_node",
    "nics_per_node", "nic_gbps", "mfu", "op_noise", "pcie_to_nic_gbps",
    "cross_switch_share",
];

/// Parse a custom chip definition; absent fields keep the
/// [`CustomChipDef::new`] defaults. Unknown keys are rejected — a typo'd
/// field would otherwise silently fall back to the default.
pub fn chip_def_from_json(v: &Value) -> Result<CustomChipDef> {
    for key in v.obj()?.keys() {
        if !CHIP_DEF_KEYS.contains(&key.as_str()) {
            bail!("unknown chip field `{key}` (expected one of {CHIP_DEF_KEYS:?})");
        }
    }
    let mut def = CustomChipDef::new(v.get("name")?.str()?);
    if let Some(x) = v.opt("fp16_tflops") {
        def.fp16_tflops = x.num()?;
    }
    if let Some(x) = v.opt("memory_gib") {
        def.memory_gib = x.num()?;
    }
    if let Some(x) = v.opt("chips_per_node") {
        def.chips_per_node = x.usize()?;
    }
    if let Some(x) = v.opt("intra_node") {
        def.intra_node = link_from_json(x)?;
    }
    if let Some(x) = v.opt("nics_per_node") {
        def.nics_per_node = x.usize()?;
    }
    if let Some(x) = v.opt("nic_gbps") {
        def.nic_gbps = x.num()?;
    }
    if let Some(x) = v.opt("mfu") {
        def.mfu = x.num()?;
    }
    if let Some(x) = v.opt("op_noise") {
        def.op_noise = x.num()?;
    }
    if let Some(x) = v.opt("pcie_to_nic_gbps") {
        def.pcie_to_nic_gbps = x.num()?;
    }
    if let Some(x) = v.opt("cross_switch_share") {
        def.cross_switch_share = x.num()?;
    }
    Ok(def)
}

fn train_to_json(t: &TrainSpec) -> Value {
    json::obj(vec![
        ("model", json::s(&t.model)),
        (
            "stages",
            json::arr(
                t.stages
                    .iter()
                    .map(|s| {
                        json::obj(vec![
                            ("prefix", json::s(&s.prefix)),
                            ("chip", json::s(s.chip.name())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("dp", json::num(t.dp as f64)),
        ("micro_batches", json::num(t.micro_batches as f64)),
        ("steps", json::num(t.steps as f64)),
        ("lr", json::num(t.lr as f64)),
        // JSON numbers are f64: a full-range u64 seed would silently lose
        // low bits above 2^53, so seeds travel as decimal strings.
        ("seed", json::s(&t.seed.to_string())),
        ("log_every", json::num(t.log_every as f64)),
    ])
}

/// Seeds are written as decimal strings (see [`train_to_json`]) but a
/// small integer is accepted for hand-written files.
fn seed_from_json(v: &Value) -> Result<u64> {
    match v {
        Value::Str(s) => s.parse::<u64>().map_err(|e| anyhow!("bad seed `{s}`: {e}")),
        _ => v.u64(),
    }
}

fn train_from_json(v: &Value) -> Result<TrainSpec> {
    let mut stages = Vec::new();
    for s in v.get("stages")?.arr()? {
        stages.push(StagePlan {
            prefix: s.get("prefix")?.str()?.to_string(),
            chip: parse_kind(s.get("chip")?)?,
        });
    }
    Ok(TrainSpec {
        model: v.get("model")?.str()?.to_string(),
        stages,
        dp: v.get("dp")?.usize()?,
        micro_batches: v.get("micro_batches")?.usize()?,
        steps: v.get("steps")?.usize()?,
        lr: v.get("lr")?.num()? as f32,
        seed: seed_from_json(v.get("seed")?)?,
        log_every: v.get("log_every")?.usize()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::H2_100B;
    use crate::hetero::homogeneous_baseline;

    fn table6_a_plan() -> ExecutionPlan {
        let exp = homogeneous_baseline(ChipKind::A);
        PlanBuilder::new("table6-a")
            .model(H2_100B)
            .cluster(exp.cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
            })
            .gbs_tokens(exp.gbs_tokens)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_produces_valid_plan() {
        let plan = table6_a_plan();
        assert_eq!(plan.version, PLAN_VERSION);
        assert_eq!(plan.micro_tokens, H2_100B.seq_len);
        assert_eq!(plan.stage_groups.len(), 1);
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn plan_matches_direct_cost_model_calls() {
        let plan = table6_a_plan();
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let direct = evaluate(&H2_100B, &groups, &plan.strategy, H2_100B.seq_len);
        let via_plan = plan.evaluate();
        assert_eq!(direct.iteration_seconds, via_plan.iteration_seconds);
        let sim_direct = simulate_iteration(
            &H2_100B, &groups, &plan.strategy, H2_100B.seq_len, &SimOptions::default(),
        );
        assert_eq!(sim_direct.iteration_seconds, plan.simulate().iteration_seconds);
    }

    #[test]
    fn json_roundtrip_identity() {
        let mut plan = table6_a_plan();
        plan.train = Some(TrainSpec {
            model: "h2_tiny".into(),
            stages: vec![
                StagePlan { prefix: "first_l2".into(), chip: ChipKind::A },
                StagePlan { prefix: "last_l2".into(), chip: ChipKind::B },
            ],
            dp: 1,
            micro_batches: 2,
            steps: 20,
            lr: 1e-3,
            seed: 42,
            log_every: 10,
        });
        let back = ExecutionPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back);
        let back2 = ExecutionPlan::from_json_str(&plan.to_json_string()).unwrap();
        assert_eq!(plan, back2);
    }

    #[test]
    fn custom_chip_plan_is_self_contained() {
        let mut def = CustomChipDef::new("PlanTest-Z7");
        def.fp16_tflops = 300.0;
        def.memory_gib = 80.0;
        def.chips_per_node = 8;
        let kind = hetero::register_custom(&def).unwrap();
        let cluster = Cluster::try_build("z7-lab", vec![(kind, 16)]).unwrap();
        let plan = PlanBuilder::new("custom-chip")
            .model(H2_100B)
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 1,
                micro_batches: 512,
                schedule: Schedule::ZeroBubbleV,
                comm_algo: CommAlgo::Hierarchical,
                plans: vec![GroupPlan { s_pp: 8, s_tp: 2, layers: 96, recompute: true }],
            })
            .gbs_tokens(512 * H2_100B.seq_len)
            .build()
            .unwrap();
        let text = plan.to_json_string();
        assert!(text.contains("PlanTest-Z7"), "custom chip must be embedded:\n{text}");
        let back = ExecutionPlan::from_json_str(&text).unwrap();
        assert_eq!(plan, back);
        assert!(back.simulate().iteration_seconds.is_finite());
    }

    #[test]
    fn validation_catches_broken_plans() {
        let mut plan = table6_a_plan();
        plan.strategy.plans[0].layers = 95; // not divisible by 16, wrong sum
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::LayersNotUniform { group: 0, layers: 95, s_pp: 16 }));
        assert!(errs.contains(&PlanError::LayersMismatch { assigned: 95, model: 96 }));

        let mut plan = table6_a_plan();
        plan.strategy.s_dp = 3;
        let errs = plan.validate().unwrap_err();
        assert!(errs
            .iter()
            .any(|e| matches!(e, PlanError::ChipAccounting { .. })));
        assert!(errs.iter().any(|e| matches!(e, PlanError::BatchMismatch { .. })));

        let mut plan = table6_a_plan();
        plan.strategy.plans[0].s_tp = 3;
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::TpNotPowerOfTwo { group: 0, s_tp: 3 }));
    }

    #[test]
    fn interleaving_must_chunk_every_stage() {
        // 96 layers over 16 stages = 6 layers/stage: v=2 and v=3 chunk it,
        // v=4 does not.
        let mut plan = table6_a_plan();
        plan.strategy.schedule = Schedule::Interleaved { virtual_stages: 2 };
        assert!(plan.validate().is_ok());
        plan.strategy.schedule = Schedule::Interleaved { virtual_stages: 4 };
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::LayersNotVirtualizable {
            group: 0,
            layers_per_stage: 6,
            virtual_stages: 4,
        }));
        plan.strategy.schedule = Schedule::Interleaved { virtual_stages: 1 };
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::VirtualStagesInvalid { virtual_stages: 1 }));
    }

    #[test]
    fn version1_alpha_files_still_load() {
        // A version-1 plan carries `alpha` at the top level and no
        // `schedule` token in the strategy; loading migrates it.
        let plan = table6_a_plan();
        let mut v = plan.to_json();
        match &mut v {
            Value::Obj(m) => {
                m.insert("version".to_string(), json::num(1.0));
                m.insert("alpha".to_string(), json::num(0.0));
                match m.get_mut("strategy") {
                    Some(Value::Obj(s)) => {
                        s.remove("schedule");
                    }
                    other => panic!("strategy must be an object, got {other:?}"),
                }
            }
            other => panic!("plan must serialize to an object, got {other:?}"),
        }
        let back = ExecutionPlan::from_json(&v).unwrap();
        assert_eq!(back.version, PLAN_VERSION);
        assert_eq!(back.strategy.schedule, Schedule::ZeroBubbleV);
        assert_eq!(back.strategy.plans, plan.strategy.plans);
        assert!(back.validate().is_ok());
        // Re-serializing writes the current schema.
        let roundtrip = ExecutionPlan::from_json(&back.to_json()).unwrap();
        assert_eq!(roundtrip, back);

        // Mid-range alphas map to interleaving — but only when the layer
        // layout chunks; this one does (6 layers/stage, v=2)...
        match &mut v {
            Value::Obj(m) => {
                m.insert("alpha".to_string(), json::num(0.5));
            }
            _ => unreachable!(),
        }
        let back = ExecutionPlan::from_json(&v).unwrap();
        assert_eq!(back.strategy.schedule,
                   Schedule::Interleaved { virtual_stages: 2 });
        assert!(back.validate().is_ok());
        // ...and a layout that cannot chunk falls back to 1F1B (what v1
        // actually executed) instead of rejecting a formerly-valid file.
        match &mut v {
            Value::Obj(m) => {
                // alpha 0.26 -> round(1/0.26) = 4 virtual stages; 6
                // layers/stage % 4 != 0, so interleaving cannot apply.
                m.insert("alpha".to_string(), json::num(0.26));
            }
            _ => unreachable!(),
        }
        let back = ExecutionPlan::from_json(&v).unwrap();
        assert_eq!(back.strategy.schedule, Schedule::OneF1B);
        assert!(back.validate().is_ok());
    }

    #[test]
    fn version2_files_migrate_to_the_ring_collective() {
        // A version-2 plan has no `comm_algo` token in its strategy; it
        // executed the hardwired flat ring, so that is what it loads as.
        let plan = table6_a_plan();
        let mut v = plan.to_json();
        match &mut v {
            Value::Obj(m) => {
                m.insert("version".to_string(), json::num(2.0));
                match m.get_mut("strategy") {
                    Some(Value::Obj(s)) => {
                        s.remove("comm_algo");
                    }
                    other => panic!("strategy must be an object, got {other:?}"),
                }
            }
            other => panic!("plan must serialize to an object, got {other:?}"),
        }
        let back = ExecutionPlan::from_json(&v).unwrap();
        assert_eq!(back.version, PLAN_VERSION);
        assert_eq!(back.strategy.comm_algo, CommAlgo::Ring);
        assert!(back.validate().is_ok());
        // Re-serializing writes the v3 schema with the token present.
        let text = back.to_json_string();
        assert!(text.contains("\"comm_algo\": \"ring\""), "{text}");

        // A bad token is rejected loudly rather than defaulted.
        match &mut v {
            Value::Obj(m) => match m.get_mut("strategy") {
                Some(Value::Obj(s)) => {
                    s.insert("comm_algo".to_string(), json::s("bogus"));
                }
                _ => unreachable!(),
            },
            _ => unreachable!(),
        }
        let err = ExecutionPlan::from_json(&v).unwrap_err().to_string();
        assert!(format!("{err:#}").contains("comm_algo") || err.contains("strategy"), "{err}");
    }

    #[test]
    fn version3_files_migrate_to_epoch_zero() {
        // A version-3 plan has neither `plan_epoch` nor `fault_plan`: it
        // loads as a freshly searched plan (epoch 0, no fault scenario).
        let plan = table6_a_plan();
        let mut v = plan.to_json();
        match &mut v {
            Value::Obj(m) => {
                m.insert("version".to_string(), json::num(3.0));
                m.remove("plan_epoch");
                assert!(m.remove("fault_plan").is_none(), "v3 file must not carry one");
            }
            other => panic!("plan must serialize to an object, got {other:?}"),
        }
        let back = ExecutionPlan::from_json(&v).unwrap();
        assert_eq!(back.version, PLAN_VERSION);
        assert_eq!(back.plan_epoch, 0);
        assert_eq!(back.fault_plan, None);
        assert!(back.validate().is_ok());
        // Re-serializing writes the v4 schema: `plan_epoch` present,
        // `fault_plan` still absent (absence round-trips losslessly).
        let text = back.to_json_string();
        assert!(text.contains("\"plan_epoch\": 0"), "{text}");
        assert!(!text.contains("fault_plan"), "{text}");
        assert_eq!(ExecutionPlan::from_json_str(&text).unwrap(), back);
    }

    #[test]
    fn version4_files_migrate_to_dense_ep1() {
        // A version-4 plan predates the expert-parallel axis: its strategy
        // has no `s_ep` token and its model has no MoE shape fields. It
        // loads as a dense plan with s_ep == 1 — exactly what it executed.
        let plan = table6_a_plan();
        let mut v = plan.to_json();
        match &mut v {
            Value::Obj(m) => {
                m.insert("version".to_string(), json::num(4.0));
                match m.get_mut("strategy") {
                    Some(Value::Obj(s)) => {
                        s.remove("s_ep");
                    }
                    other => panic!("strategy must be an object, got {other:?}"),
                }
                match m.get_mut("model") {
                    Some(Value::Obj(mo)) => {
                        mo.remove("n_experts");
                        mo.remove("top_k");
                        mo.remove("expert_intermediate");
                    }
                    other => panic!("model must be an object, got {other:?}"),
                }
            }
            other => panic!("plan must serialize to an object, got {other:?}"),
        }
        let back = ExecutionPlan::from_json(&v).unwrap();
        assert_eq!(back.version, PLAN_VERSION);
        assert_eq!(back.strategy.s_ep, 1);
        assert_eq!(back.model.n_experts, 0);
        assert!(!back.model.is_moe());
        assert_eq!(back, plan, "v4 migration must be lossless");
        assert!(back.validate().is_ok());
        // Re-serializing writes the v5 schema with the new fields present.
        let text = back.to_json_string();
        assert!(text.contains("\"s_ep\": 1"), "{text}");
        assert!(text.contains("\"n_experts\": 0"), "{text}");
        assert_eq!(ExecutionPlan::from_json_str(&text).unwrap(), back);
    }

    #[test]
    fn ep_validation_rules() {
        // Keep the fixture's 96-layer geometry and bolt an expert bank on,
        // so only the EP rules fire.
        let moe = |m: &ModelShape| ModelShape {
            n_experts: 8,
            top_k: 2,
            expert_intermediate: m.intermediate,
            ..*m
        };
        // Dense plan with s_ep > 1 is rejected.
        let mut plan = table6_a_plan();
        plan.strategy.s_ep = 2;
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::EpWithoutExperts { s_ep: 2 }), "{errs:?}");
        // s_ep = 0 is rejected.
        plan.strategy.s_ep = 0;
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::ZeroEp), "{errs:?}");
        // MoE shape: s_ep must divide both s_dp and n_experts.
        let mut plan = table6_a_plan();
        plan.model = moe(&plan.model);
        plan.strategy.s_ep = 3; // divides neither s_dp=4 nor n_experts=8
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::EpNotInDp { s_ep: 3, s_dp: 4 }), "{errs:?}");
        assert!(
            errs.contains(&PlanError::EpNotInExperts { s_ep: 3, n_experts: 8 }),
            "{errs:?}"
        );
        // A valid EP degree (divides both) passes.
        plan.strategy.s_ep = 4;
        assert!(plan.validate().is_ok(), "{:?}", plan.validate());
        // A broken MoE shape is caught too.
        plan.model.top_k = 0;
        let errs = plan.validate().unwrap_err();
        assert!(
            errs.iter().any(|e| matches!(e, PlanError::MoeShapeInvalid { .. })),
            "{errs:?}"
        );
    }

    #[test]
    fn fault_plan_and_epoch_roundtrip() {
        use crate::elastic::fault::{FaultEvent, FaultKind};
        let mut plan = table6_a_plan();
        plan.plan_epoch = 3;
        plan.fault_plan = Some(FaultPlan {
            seed: 7,
            events: vec![
                FaultEvent { step: 2, stage: 1, kind: FaultKind::Slowdown { factor: 2.0 } },
                FaultEvent { step: 5, stage: 3, kind: FaultKind::ChipDeath { nodes: 1 } },
            ],
        });
        assert!(plan.validate().is_ok());
        let back = ExecutionPlan::from_json_str(&plan.to_json_string()).unwrap();
        assert_eq!(back, plan);

        // A fault plan naming a stage the strategy doesn't have is caught
        // by plan validation, not left for the executors to trip over.
        plan.fault_plan = Some(FaultPlan {
            seed: 7,
            events: vec![FaultEvent {
                step: 0,
                stage: 99,
                kind: FaultKind::Recover,
            }],
        });
        let errs = plan.validate().unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, PlanError::FaultPlanInvalid { .. })), "{errs:?}");
    }

    #[test]
    fn comm_algo_tokens_roundtrip_through_plans() {
        let mut plan = table6_a_plan();
        for algo in CommAlgo::ALL {
            plan.strategy.comm_algo = algo;
            let back = ExecutionPlan::from_json(&plan.to_json()).unwrap();
            assert_eq!(back.strategy.comm_algo, algo);
            assert_eq!(back, plan);
        }
    }

    #[test]
    fn stage_groups_must_repartition_cluster() {
        let mut plan = table6_a_plan();
        plan.cluster = Cluster::new("bigger", vec![(ChipKind::A, 512)]);
        let errs = plan.validate().unwrap_err();
        assert!(errs.contains(&PlanError::ClusterMismatch {
            chip: "Chip-A".into(),
            cluster: 512,
            stages: 256,
        }));
    }

    #[test]
    fn train_config_carries_the_plan_strategy() {
        // The coordinator is a plan evaluator: the lowered TrainConfig
        // must carry the plan's schedule and collective algorithm instead
        // of rejecting non-1F1B schedules.
        let mut plan = table6_a_plan();
        plan.train = Some(TrainSpec {
            model: "h2_tiny".into(),
            stages: vec![
                StagePlan { prefix: "first_l2".into(), chip: ChipKind::A },
                StagePlan { prefix: "last_l2".into(), chip: ChipKind::B },
            ],
            dp: 1,
            micro_batches: 2,
            steps: 20,
            lr: 1e-3,
            seed: 42,
            log_every: 10,
        });
        plan.strategy.schedule = Schedule::ZeroBubbleV;
        plan.strategy.comm_algo = CommAlgo::Hierarchical;
        let cfg = plan.train_config().unwrap();
        assert_eq!(cfg.schedule, Schedule::ZeroBubbleV);
        assert_eq!(cfg.comm_algo, CommAlgo::Hierarchical);
        plan.train = None;
        assert!(plan.train_config().is_err(), "no train section must error");
    }

    #[test]
    fn train_role_mismatch_is_reported() {
        let mut plan = table6_a_plan();
        plan.train = Some(TrainSpec {
            model: "h2_tiny".into(),
            stages: vec![
                StagePlan { prefix: "mid_l2".into(), chip: ChipKind::A },
                StagePlan { prefix: "last_l2".into(), chip: ChipKind::B },
            ],
            dp: 1,
            micro_batches: 2,
            steps: 20,
            lr: 1e-3,
            seed: 42,
            log_every: 10,
        });
        let errs = plan.validate().unwrap_err();
        assert!(errs.iter().any(|e| matches!(
            e,
            PlanError::TrainStageRole { index: 0, expected: "first", .. }
        )));
    }

    #[test]
    fn load_save_roundtrip() {
        let dir = std::env::temp_dir().join("h2_plan_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        let path = path.to_str().unwrap();
        let plan = table6_a_plan();
        plan.save(path).unwrap();
        let back = ExecutionPlan::load(path).unwrap();
        assert_eq!(plan, back);
    }
}
