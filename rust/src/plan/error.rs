//! Structured validation errors for [`super::ExecutionPlan`].
//!
//! Every way a plan can be malformed gets its own variant, so callers
//! (the CLI, the builder, tests) can match on the failure instead of
//! string-scraping `anyhow` messages. [`super::ExecutionPlan::validate`]
//! collects *all* violations, not just the first.

use std::fmt;

/// One structural violation in an [`super::ExecutionPlan`].
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// The builder was never given a cluster.
    MissingCluster,
    /// The builder was never given a strategy.
    MissingStrategy,
    /// No chip groups at all.
    EmptyGroups,
    /// `groups.len() != strategy.plans.len()` — the positional pairing the
    /// whole cost model relies on is broken.
    GroupsMismatch { groups: usize, plans: usize },
    /// Per-chip-kind totals of `stage_groups` don't repartition the cluster
    /// (TGS divides by the cluster's chips; simulation runs the stage groups).
    ClusterMismatch { chip: String, cluster: usize, stages: usize },
    /// Assigned layers don't sum to the model's layer count.
    LayersMismatch { assigned: usize, model: usize },
    /// A group was assigned zero layers.
    ZeroLayers { group: usize },
    /// A group's layers don't split evenly over its pipeline stages.
    LayersNotUniform { group: usize, layers: usize, s_pp: usize },
    /// `s_pp * s_tp * s_dp` doesn't account for every chip of the group.
    ChipAccounting { group: usize, chips: usize, s_pp: usize, s_tp: usize, s_dp: usize },
    /// Tensor-parallel degree is not a power of two.
    TpNotPowerOfTwo { group: usize, s_tp: usize },
    /// Tensor-parallel degree exceeds the chip's uniform-bandwidth island.
    TpExceedsMax { group: usize, s_tp: usize, tp_max: usize },
    /// A group's chip count is not a whole number of nodes.
    PartialNode { group: usize, chips: usize, chips_per_node: usize },
    /// Data-parallel degree of zero.
    ZeroDp,
    /// No micro-batches per pipeline.
    ZeroMicroBatches,
    /// The global batch's sequences don't split over `s_dp` replicas into
    /// the declared micro-batch count.
    BatchMismatch { sequences: usize, s_dp: usize, micro_batches: usize },
    /// Global batch smaller than one sequence.
    BatchBelowOneSequence { gbs_tokens: usize, micro_tokens: usize },
    /// Global batch is not a whole number of micro-batches — the remainder
    /// tokens would be silently dropped by every consumer.
    TokensNotWholeSequences { gbs_tokens: usize, micro_tokens: usize },
    /// Zero-token micro-batches.
    ZeroMicroTokens,
    /// Interleaved schedule with fewer than two virtual stages (that is
    /// just 1F1B and the chunk math degenerates).
    VirtualStagesInvalid { virtual_stages: usize },
    /// A group's per-stage layer count is not divisible by the interleaved
    /// schedule's virtual-stage count, so the stage cannot be chunked.
    LayersNotVirtualizable { group: usize, layers_per_stage: usize, virtual_stages: usize },
    /// A train-section stage prefix doesn't match its pipeline role.
    TrainStageRole { index: usize, prefix: String, expected: &'static str },
    /// The train section is structurally empty.
    TrainEmpty,
    /// The embedded fault-injection scenario is malformed (stage out of
    /// range, non-positive factor, zero-node death).
    FaultPlanInvalid { detail: String },
    /// Expert-parallel degree of zero (dense plans carry `s_ep == 1`).
    ZeroEp,
    /// Expert-parallel degree does not divide the data-parallel degree
    /// (EP groups are carved out of the DP replicas).
    EpNotInDp { s_ep: usize, s_dp: usize },
    /// Expert-parallel degree does not divide the expert count, so the
    /// expert bank cannot shard evenly.
    EpNotInExperts { s_ep: usize, n_experts: usize },
    /// A dense model (no experts) with an expert-parallel degree above 1.
    EpWithoutExperts { s_ep: usize },
    /// The MoE shape is internally inconsistent (`top_k` outside
    /// `1..=n_experts`, or a zero expert FFN width).
    MoeShapeInvalid { n_experts: usize, top_k: usize, expert_intermediate: usize },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::MissingCluster => write!(f, "plan has no cluster"),
            PlanError::MissingStrategy => write!(f, "plan has no strategy"),
            PlanError::EmptyGroups => write!(f, "plan has no chip groups"),
            PlanError::GroupsMismatch { groups, plans } => {
                write!(f, "{groups} chip groups but {plans} group plans")
            }
            PlanError::ClusterMismatch { chip, cluster, stages } => {
                write!(f, "{chip}: stage groups hold {stages} chips but the \
                           cluster has {cluster}")
            }
            PlanError::LayersMismatch { assigned, model } => {
                write!(f, "assigned {assigned} layers but the model has {model}")
            }
            PlanError::ZeroLayers { group } => write!(f, "group {group} has zero layers"),
            PlanError::LayersNotUniform { group, layers, s_pp } => {
                write!(f, "group {group}: {layers} layers do not split over {s_pp} stages")
            }
            PlanError::ChipAccounting { group, chips, s_pp, s_tp, s_dp } => {
                write!(f, "group {group}: {s_pp}(pp) x {s_tp}(tp) x {s_dp}(dp) != {chips} chips")
            }
            PlanError::TpNotPowerOfTwo { group, s_tp } => {
                write!(f, "group {group}: s_tp {s_tp} is not a power of two")
            }
            PlanError::TpExceedsMax { group, s_tp, tp_max } => {
                write!(f, "group {group}: s_tp {s_tp} exceeds TP_MAX {tp_max}")
            }
            PlanError::PartialNode { group, chips, chips_per_node } => {
                write!(f, "group {group}: {chips} chips is not a whole number of \
                           {chips_per_node}-chip nodes")
            }
            PlanError::ZeroDp => write!(f, "s_dp must be >= 1"),
            PlanError::ZeroMicroBatches => write!(f, "micro_batches must be >= 1"),
            PlanError::BatchMismatch { sequences, s_dp, micro_batches } => {
                write!(f, "{sequences} sequences != {s_dp}(dp) x {micro_batches}(micro-batches)")
            }
            PlanError::BatchBelowOneSequence { gbs_tokens, micro_tokens } => {
                write!(f, "global batch of {gbs_tokens} tokens is below one \
                           {micro_tokens}-token sequence")
            }
            PlanError::TokensNotWholeSequences { gbs_tokens, micro_tokens } => {
                write!(f, "global batch of {gbs_tokens} tokens is not a whole \
                           number of {micro_tokens}-token micro-batches")
            }
            PlanError::ZeroMicroTokens => write!(f, "micro_tokens must be >= 1"),
            PlanError::VirtualStagesInvalid { virtual_stages } => {
                write!(f, "interleaved schedule needs >= 2 virtual stages, got \
                           {virtual_stages}")
            }
            PlanError::LayersNotVirtualizable { group, layers_per_stage, virtual_stages } => {
                write!(f, "group {group}: {layers_per_stage} layers/stage do not chunk \
                           into {virtual_stages} virtual stages")
            }
            PlanError::TrainStageRole { index, prefix, expected } => {
                write!(f, "train stage {index}: prefix `{prefix}` does not match \
                           role `{expected}`")
            }
            PlanError::TrainEmpty => write!(f, "train section has no stages"),
            PlanError::FaultPlanInvalid { detail } => {
                write!(f, "fault plan is invalid: {detail}")
            }
            PlanError::ZeroEp => write!(f, "s_ep must be >= 1"),
            PlanError::EpNotInDp { s_ep, s_dp } => {
                write!(f, "s_ep {s_ep} does not divide s_dp {s_dp}")
            }
            PlanError::EpNotInExperts { s_ep, n_experts } => {
                write!(f, "s_ep {s_ep} does not divide n_experts {n_experts}")
            }
            PlanError::EpWithoutExperts { s_ep } => {
                write!(f, "s_ep {s_ep} > 1 on a dense model (no experts to shard)")
            }
            PlanError::MoeShapeInvalid { n_experts, top_k, expert_intermediate } => {
                write!(f, "MoE shape invalid: n_experts {n_experts}, top_k {top_k}, \
                           expert_intermediate {expert_intermediate}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Render a violation list as a one-per-line report (CLI error output).
pub fn render_errors(errors: &[PlanError]) -> String {
    errors
        .iter()
        .map(|e| format!("  - {e}"))
        .collect::<Vec<_>>()
        .join("\n")
}
