//! Typed construction of [`ExecutionPlan`]s with up-front validation.

use crate::comm::{CommAlgo, CommMode};
use crate::costmodel::{ModelShape, Schedule, Strategy, H2_100B};
use crate::hetero::{ChipGroup, Cluster};
use crate::sim::ReshardStrategy;
use crate::topology::NicAssignment;

use super::{ExecutionPlan, PlanError, PrecisionPolicy, TrainSpec, PLAN_VERSION};

/// Builder for [`ExecutionPlan`]: set the cluster and strategy, override
/// whatever else differs from the paper defaults, then [`PlanBuilder::build`].
///
/// Defaults: 100B model, GBS 2M tokens, micro-batch of one sequence,
/// device-direct RDMA, SR&AG resharding, NIC affinity, fine-grained
/// overlap on. The pipeline schedule and DP-collective algorithm travel
/// inside the strategy; [`PlanBuilder::schedule`] and
/// [`PlanBuilder::comm_algo`] override them.
#[derive(Clone, Debug)]
pub struct PlanBuilder {
    name: String,
    model: ModelShape,
    cluster: Option<Cluster>,
    stage_groups: Option<Vec<ChipGroup>>,
    strategy: Option<Strategy>,
    gbs_tokens: usize,
    micro_tokens: Option<usize>,
    schedule: Option<Schedule>,
    comm_algo: Option<CommAlgo>,
    comm: CommMode,
    reshard: ReshardStrategy,
    nic_assignment: NicAssignment,
    fine_overlap: bool,
    precision: PrecisionPolicy,
    train: Option<TrainSpec>,
}

impl PlanBuilder {
    /// Start a builder with the paper defaults under the given plan name.
    pub fn new(name: &str) -> PlanBuilder {
        PlanBuilder {
            name: name.to_string(),
            model: H2_100B,
            cluster: None,
            stage_groups: None,
            strategy: None,
            gbs_tokens: 2 * 1024 * 1024,
            micro_tokens: None,
            schedule: None,
            comm_algo: None,
            comm: CommMode::DeviceDirect,
            reshard: ReshardStrategy::SendRecvAllGather,
            nic_assignment: NicAssignment::Affinity,
            fine_overlap: true,
            precision: PrecisionPolicy::default(),
            train: None,
        }
    }

    /// Override the model shape (default: the 100B of Table 4).
    pub fn model(mut self, model: ModelShape) -> Self {
        self.model = model;
        self
    }

    /// The physical cluster. Unless [`PlanBuilder::stage_groups`] is also
    /// called, stage groups default to the cluster's groups in
    /// memory-descending HeteroPP order.
    pub fn cluster(mut self, cluster: Cluster) -> Self {
        self.cluster = Some(cluster);
        self
    }

    /// Explicit stage-ordered groups (e.g. the two-stage search's
    /// pseudo-subgroups), positionally matched with `strategy.plans`.
    pub fn stage_groups(mut self, groups: Vec<ChipGroup>) -> Self {
        self.stage_groups = Some(groups);
        self
    }

    /// The parallel strategy (its `schedule` field is kept unless
    /// [`PlanBuilder::schedule`] overrides it).
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = Some(strategy);
        self
    }

    /// Global batch size in tokens (default: the paper's 2M).
    pub fn gbs_tokens(mut self, gbs_tokens: usize) -> Self {
        self.gbs_tokens = gbs_tokens;
        self
    }

    /// Tokens per micro-batch; defaults to the model's sequence length.
    pub fn micro_tokens(mut self, micro_tokens: usize) -> Self {
        self.micro_tokens = Some(micro_tokens);
        self
    }

    /// Override the strategy's pipeline schedule (e.g. a config or CLI
    /// `--schedule` layered over a searched strategy).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Override the strategy's DP-collective algorithm (e.g. a config or
    /// CLI `--comm-algo` layered over a searched strategy).
    pub fn comm_algo(mut self, comm_algo: CommAlgo) -> Self {
        self.comm_algo = Some(comm_algo);
        self
    }

    /// Cross-chip communication strategy.
    pub fn comm(mut self, comm: CommMode) -> Self {
        self.comm = comm;
        self
    }

    /// Inter-stage activation resharding strategy.
    pub fn reshard(mut self, reshard: ReshardStrategy) -> Self {
        self.reshard = reshard;
        self
    }

    /// NIC selection policy.
    pub fn nic_assignment(mut self, nic_assignment: NicAssignment) -> Self {
        self.nic_assignment = nic_assignment;
        self
    }

    /// Toggle fine-grained P2P/compute overlap.
    pub fn fine_overlap(mut self, fine_overlap: bool) -> Self {
        self.fine_overlap = fine_overlap;
        self
    }

    /// Numeric-precision policy for real training runs.
    pub fn precision(mut self, precision: PrecisionPolicy) -> Self {
        self.precision = precision;
        self
    }

    /// Attach a real-training section (`h2 train --plan`).
    pub fn train(mut self, train: TrainSpec) -> Self {
        self.train = Some(train);
        self
    }

    /// Assemble and validate. Returns every violation, not just the first.
    pub fn build(self) -> Result<ExecutionPlan, Vec<PlanError>> {
        let mut errs = Vec::new();
        if self.cluster.is_none() {
            errs.push(PlanError::MissingCluster);
        }
        if self.strategy.is_none() {
            errs.push(PlanError::MissingStrategy);
        }
        if !errs.is_empty() {
            return Err(errs);
        }
        let cluster = self.cluster.unwrap();
        let stage_groups = self.stage_groups.unwrap_or_else(|| {
            cluster.groups_by_memory_desc().into_iter().cloned().collect()
        });
        let mut strategy = self.strategy.unwrap();
        if let Some(schedule) = self.schedule {
            strategy.schedule = schedule;
        }
        if let Some(comm_algo) = self.comm_algo {
            strategy.comm_algo = comm_algo;
        }
        let plan = ExecutionPlan {
            version: PLAN_VERSION,
            name: self.name,
            model: self.model,
            cluster,
            stage_groups,
            strategy,
            gbs_tokens: self.gbs_tokens,
            micro_tokens: self.micro_tokens.unwrap_or(self.model.seq_len),
            comm: self.comm,
            reshard: self.reshard,
            nic_assignment: self.nic_assignment,
            fine_overlap: self.fine_overlap,
            precision: self.precision,
            train: self.train,
            plan_epoch: 0,
            fault_plan: None,
        };
        plan.validate()?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GroupPlan;
    use crate::hetero::ChipKind;

    #[test]
    fn missing_parts_are_reported_together() {
        let errs = PlanBuilder::new("empty").build().unwrap_err();
        assert!(errs.contains(&PlanError::MissingCluster));
        assert!(errs.contains(&PlanError::MissingStrategy));
    }

    #[test]
    fn stage_groups_default_to_memory_order() {
        let cluster = Cluster::new(
            "ba",
            vec![(ChipKind::B, 256), (ChipKind::A, 256)],
        );
        let plan = PlanBuilder::new("order")
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 16, s_tp: 4, layers: 48, recompute: false },
                    GroupPlan { s_pp: 16, s_tp: 4, layers: 48, recompute: true },
                ],
            })
            .build()
            .unwrap();
        // A (96 GiB) must come before B (64 GiB) regardless of input order.
        assert_eq!(plan.stage_groups[0].spec.kind, ChipKind::A);
        assert_eq!(plan.stage_groups[1].spec.kind, ChipKind::B);
    }

    #[test]
    fn schedule_and_comm_algo_overrides_win_over_the_strategy() {
        let cluster = Cluster::new("a", vec![(ChipKind::A, 256)]);
        let plan = PlanBuilder::new("override")
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
            })
            .schedule(Schedule::ZeroBubbleV)
            .comm_algo(CommAlgo::Hierarchical)
            .build()
            .unwrap();
        assert_eq!(plan.strategy.schedule, Schedule::ZeroBubbleV);
        assert_eq!(plan.strategy.comm_algo, CommAlgo::Hierarchical);
    }
}
