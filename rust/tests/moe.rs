//! The `exp-moe` fixture end to end: HeteroAuto's free search over the
//! expert-parallel axis must find an EP>1 layout that beats the best
//! dense-style EP=1 layout in all three evaluators — the §4.3.2
//! closed-form cost model, the discrete-event simulator, and the
//! coordinator's executing virtual run — with the winner surviving the
//! plan JSON v5 round-trip bit for bit.
//!
//! The fixture is built so the verdict is structural, not a numerical
//! coin-flip: at EP=1 every chip carries the full 32-expert bank
//! (~7B parameters per layer), which overflows the 0.92 memory budget on
//! every feasible layout and degrades the plan to PCIe optimizer offload;
//! any EP>1 shard fits cleanly. The margin is therefore the offload
//! cliff — several-fold, visible to every evaluator that prices memory.

use h2::auto::{search, SearchConfig, SearchResult};
use h2::coordinator::{train_virtual, VirtualOptions};
use h2::costmodel::{Schedule, H2_MOE};
use h2::hetero::experiment;
use h2::plan::{ExecutionPlan, PLAN_VERSION};

/// Single-stage DFS (both 64-chip groups sit under the 128-chip split
/// threshold anyway) with the DP axis capped at 8 to keep the sweep
/// seconds-fast; every EP candidate reachable at dp <= 8 stays in play.
fn moe_cfg() -> SearchConfig {
    SearchConfig { two_stage: false, max_dp: 8, ..SearchConfig::pinned(Schedule::OneF1B) }
}

fn run(max_ep: usize) -> SearchResult {
    let exp = experiment("exp-moe").unwrap();
    let cfg = SearchConfig { max_ep, ..moe_cfg() };
    search(&H2_MOE, &exp.cluster, exp.gbs_tokens, &cfg).unwrap()
}

#[test]
fn free_search_picks_expert_parallelism_over_the_offloaded_dense_layout() {
    let free = run(0);
    let pinned = run(1);
    assert!(free.eval.feasible && pinned.eval.feasible);
    assert_eq!(pinned.strategy.s_ep, 1);
    assert!(
        free.strategy.s_ep > 1,
        "free search stayed at EP=1 ({}s)",
        free.eval.iteration_seconds
    );
    // The EP shard must divide both the expert bank and the DP degree.
    assert_eq!(H2_MOE.n_experts % free.strategy.s_ep, 0);
    assert_eq!(free.strategy.s_dp % free.strategy.s_ep, 0);
    // The EP=1 side pays the offload cliff; the margin is structural, so
    // demand a decisive win, not an epsilon.
    assert!(
        free.eval.iteration_seconds < pinned.eval.iteration_seconds * 0.5,
        "EP win not decisive: free {} vs pinned {}",
        free.eval.iteration_seconds,
        pinned.eval.iteration_seconds
    );
}

#[test]
fn ep_winner_beats_ep1_in_simulator_and_virtual_coordinator() {
    let exp = experiment("exp-moe").unwrap();
    let free = run(0);
    let pinned = run(1);
    assert!(free.strategy.s_ep > 1 && pinned.strategy.s_ep == 1);
    let free_ep = free.strategy.s_ep;

    let free_plan = free.into_plan(&H2_MOE, &exp.cluster, exp.gbs_tokens);
    let pinned_plan = pinned.into_plan(&H2_MOE, &exp.cluster, exp.gbs_tokens);
    free_plan.validate().unwrap();
    pinned_plan.validate().unwrap();

    // Plan JSON v5 round-trip, bit for bit, keeping the MoE shape + EP.
    assert_eq!(free_plan.version, PLAN_VERSION);
    let loaded = ExecutionPlan::from_json_str(&free_plan.to_json_string()).unwrap();
    assert_eq!(loaded, free_plan);
    assert_eq!(loaded.strategy.s_ep, free_ep);
    assert_eq!(loaded.model.n_experts, H2_MOE.n_experts);

    // Discrete-event simulator: same ordering as the closed form.
    let sim_free = loaded.simulate().iteration_seconds;
    let sim_pinned = pinned_plan.simulate().iteration_seconds;
    assert!(
        sim_free < sim_pinned,
        "simulator disagrees: EP{free_ep} {sim_free} !< EP1 {sim_pinned}"
    );

    // Executing virtual coordinator: real op orders over the thread
    // fabric, modeled clock — the sharpest evaluator must order the same.
    let opts = VirtualOptions { steps: 2, ..Default::default() };
    let tv_free = train_virtual(&loaded, &opts).unwrap().step_seconds;
    let tv_pinned = train_virtual(&pinned_plan, &opts).unwrap().step_seconds;
    assert!(
        tv_free < tv_pinned,
        "coordinator disagrees: EP{free_ep} {tv_free} !< EP1 {tv_pinned}"
    );
}

#[test]
fn moe_search_is_deterministic_across_parallel_and_sequential() {
    let exp = experiment("exp-moe").unwrap();
    let par = search(&H2_MOE, &exp.cluster, exp.gbs_tokens, &moe_cfg()).unwrap();
    let seq_cfg = SearchConfig { parallel: false, ..moe_cfg() };
    let seq = search(&H2_MOE, &exp.cluster, exp.gbs_tokens, &seq_cfg).unwrap();
    assert_eq!(par.strategy, seq.strategy);
    assert_eq!(
        par.eval.iteration_seconds.to_bits(),
        seq.eval.iteration_seconds.to_bits(),
        "parallel {} vs sequential {}",
        par.eval.iteration_seconds,
        seq.eval.iteration_seconds
    );
}
