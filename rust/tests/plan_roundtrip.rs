//! Property test: `ExecutionPlan::from_json(plan.to_json())` is the
//! identity over randomized plans — arbitrary models, clusters (including
//! a runtime-registered custom chip), strategies, communication options and
//! train sections. Serialization must be lossless even for plans that
//! would fail validation, so plans are assembled directly rather than
//! through the builder.

use h2::comm::{CommAlgo, CommMode};
use h2::coordinator::StagePlan;
use h2::costmodel::{GroupPlan, ModelShape, Schedule, Strategy};
use h2::elastic::FaultPlan;
use h2::hetero::{register_custom, ChipGroup, ChipKind, Cluster, CustomChipDef, IntraNodeLink};
use h2::plan::{ExecutionPlan, PlanBuilder, PrecisionPolicy, TrainSpec, PLAN_VERSION};
use h2::sim::ReshardStrategy;
use h2::topology::NicAssignment;
use h2::util::prop;
use h2::util::rng::Rng;

fn random_model(rng: &mut Rng) -> ModelShape {
    let n_heads = 1 << rng.usize(2, 7);
    let head_dim = 1 << rng.usize(5, 8);
    // Half the models are MoE: the serializer must round-trip both the
    // dense all-zero shape and arbitrary expert banks.
    let n_experts = if rng.f64() < 0.5 { 0 } else { rng.usize(2, 17) };
    ModelShape {
        n_layers: rng.usize(1, 129),
        hidden: n_heads * head_dim,
        n_heads,
        n_kv_heads: 1 << rng.usize(0, 4),
        intermediate: rng.usize(1024, 40_000),
        vocab: rng.usize(1000, 100_000),
        seq_len: 1 << rng.usize(8, 14),
        n_experts,
        top_k: if n_experts == 0 { 0 } else { rng.usize(1, n_experts) },
        expert_intermediate: if n_experts == 0 { 0 } else { rng.usize(1024, 40_000) },
    }
}

fn random_link(rng: &mut Rng) -> IntraNodeLink {
    match rng.usize(0, 3) {
        0 => IntraNodeLink::Uniform { gbps: rng.f64() * 500.0 + 1.0 },
        1 => IntraNodeLink::NumaSplit {
            local_gbps: rng.f64() * 300.0 + 1.0,
            cross_gbps: rng.f64() * 100.0 + 1.0,
            island: 1 << rng.usize(1, 4),
        },
        _ => IntraNodeLink::PcieSwitch {
            local_gbps: rng.f64() * 100.0 + 1.0,
            cross_gbps: rng.f64() * 50.0 + 1.0,
            group: 1 << rng.usize(1, 4),
        },
    }
}

fn random_custom_kind(rng: &mut Rng) -> ChipKind {
    let mut def = CustomChipDef::new("PropRT-X");
    def.fp16_tflops = rng.f64() * 900.0 + 10.0;
    def.memory_gib = rng.f64() * 120.0 + 8.0;
    def.chips_per_node = 1 << rng.usize(0, 5);
    def.intra_node = random_link(rng);
    def.nics_per_node = rng.usize(1, 9);
    def.nic_gbps = rng.f64() * 40.0 + 1.0;
    def.mfu = rng.f64() * 0.6 + 0.2;
    def.op_noise = rng.f64() * 0.02;
    def.pcie_to_nic_gbps = rng.f64() * 20.0 + 1.0;
    def.cross_switch_share = rng.f64() * 0.5 + 0.3;
    register_custom(&def).unwrap()
}

fn random_groups(rng: &mut Rng, custom: ChipKind) -> Vec<ChipGroup> {
    let n = rng.usize(1, 4);
    (0..n)
        .map(|_| {
            let kind = match rng.usize(0, 6) {
                0 => ChipKind::A,
                1 => ChipKind::B,
                2 => ChipKind::C,
                3 => ChipKind::D,
                4 => ChipKind::A100,
                _ => custom,
            };
            let node = h2::hetero::spec(kind).chips_per_node;
            ChipGroup::try_new(kind, node * rng.usize(1, 9)).unwrap()
        })
        .collect()
}

fn random_schedule(rng: &mut Rng) -> Schedule {
    match rng.usize(0, 3) {
        0 => Schedule::OneF1B,
        1 => Schedule::Interleaved { virtual_stages: rng.usize(2, 9) },
        _ => Schedule::ZeroBubbleV,
    }
}

fn random_comm_algo(rng: &mut Rng) -> CommAlgo {
    match rng.usize(0, 5) {
        0 => CommAlgo::Ring,
        1 => CommAlgo::Tree,
        2 => CommAlgo::RecursiveHalvingDoubling,
        3 => CommAlgo::Hierarchical,
        _ => CommAlgo::Auto,
    }
}

fn random_strategy(rng: &mut Rng, n_groups: usize) -> Strategy {
    Strategy {
        s_ep: rng.usize(1, 9),
        s_dp: rng.usize(1, 65),
        micro_batches: rng.usize(1, 1025),
        schedule: random_schedule(rng),
        comm_algo: random_comm_algo(rng),
        plans: (0..n_groups)
            .map(|_| GroupPlan {
                s_pp: rng.usize(1, 65),
                s_tp: 1 << rng.usize(0, 5),
                layers: rng.usize(1, 129),
                recompute: rng.f64() < 0.5,
            })
            .collect(),
    }
}

fn random_plan(rng: &mut Rng) -> ExecutionPlan {
    let custom = random_custom_kind(rng);
    let groups = random_groups(rng, custom);
    let strategy = random_strategy(rng, groups.len());
    let comms = [CommMode::TcpCpu, CommMode::RdmaCpu, CommMode::DeviceDirect];
    let reshards = [
        ReshardStrategy::NaiveP2p,
        ReshardStrategy::Broadcast,
        ReshardStrategy::SendRecvAllGather,
    ];
    let train = (rng.f64() < 0.5).then(|| TrainSpec {
        model: format!("model_{}", rng.usize(0, 100)),
        stages: vec![
            StagePlan { prefix: "first_l2".into(), chip: *rng.choose(&[ChipKind::A, custom]) },
            StagePlan { prefix: "last_l2".into(), chip: *rng.choose(&[ChipKind::B, custom]) },
        ],
        dp: rng.usize(1, 9),
        micro_batches: rng.usize(1, 17),
        steps: rng.usize(1, 1000),
        lr: rng.f32(),
        seed: rng.next_u64(),
        log_every: rng.usize(0, 100),
    });
    ExecutionPlan {
        version: PLAN_VERSION,
        name: format!("prop-{}", rng.usize(0, 1_000_000)),
        model: random_model(rng),
        cluster: Cluster { name: "prop-cluster".into(), groups: groups.clone() },
        stage_groups: groups,
        strategy,
        gbs_tokens: rng.usize(1, 1 << 24),
        micro_tokens: rng.usize(1, 1 << 14),
        comm: *rng.choose(&comms),
        reshard: *rng.choose(&reshards),
        nic_assignment: if rng.f64() < 0.5 {
            NicAssignment::Affinity
        } else {
            NicAssignment::NonAffinity
        },
        fine_overlap: rng.f64() < 0.5,
        precision: PrecisionPolicy { perturb: rng.f64() < 0.5, mre_threshold: rng.f64() * 0.1 },
        train,
        // plan_epoch serializes as a JSON number (f64): keep it well
        // under 2^53 so the round-trip is exact.
        plan_epoch: rng.range(0, 1 << 20),
        fault_plan: (rng.f64() < 0.5).then(|| {
            FaultPlan::generate(rng.next_u64(), rng.usize(2, 32), rng.usize(1, 9),
                                rng.f64() < 0.5)
        }),
    }
}

#[test]
fn from_json_to_json_is_identity() {
    prop::check(300, |rng: &mut Rng| {
        let plan = random_plan(rng);
        let value = plan.to_json();
        let back = ExecutionPlan::from_json(&value)
            .map_err(|e| format!("from_json failed: {e:#}"))?;
        // The schedule and comm algo are the newest fields — call out
        // their drift explicitly before the whole-plan comparison.
        prop::assert_prop(
            back.strategy.schedule == plan.strategy.schedule,
            format!("schedule drift: {} vs {}", plan.strategy.schedule,
                    back.strategy.schedule),
        )?;
        prop::assert_prop(
            back.strategy.comm_algo == plan.strategy.comm_algo,
            format!("comm-algo drift: {} vs {}", plan.strategy.comm_algo,
                    back.strategy.comm_algo),
        )?;
        prop::assert_prop(back == plan, format!("round-trip drift:\n{plan:?}\nvs\n{back:?}"))?;
        // And through the textual form (what plan files actually hold).
        let back2 = ExecutionPlan::from_json_str(&plan.to_json_string())
            .map_err(|e| format!("from_json_str failed: {e:#}"))?;
        prop::assert_prop(back2 == plan, "textual round-trip drift")
    });
}

#[test]
fn valid_plans_stay_valid_across_roundtrip() {
    // Builder-validated plans must still validate after save/load.
    let exp = h2::hetero::homogeneous_baseline(ChipKind::B);
    let plan = PlanBuilder::new("rt-valid")
        .cluster(exp.cluster)
        .strategy(Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule: Schedule::Interleaved { virtual_stages: 2 },
            comm_algo: CommAlgo::Auto,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: true }],
        })
        .gbs_tokens(exp.gbs_tokens)
        .build()
        .unwrap();
    let back = ExecutionPlan::from_json(&plan.to_json()).unwrap();
    assert!(back.validate().is_ok());
    assert_eq!(back, plan);
    // The deserialized plan drives the simulator to the same result.
    assert_eq!(
        plan.simulate().iteration_seconds,
        back.simulate().iteration_seconds
    );
}
