//! Differential proptest: the flat-arena [`SimEngine`] against the
//! pre-refactor executors preserved in `h2::sim::reference`.
//!
//! Arbitrary small clusters × schedules × comm-algos × sim options must
//! produce bit-identical results AND bit-identical event timelines on both
//! paths; arbitrary seeded `FaultPlan`s must produce bit-identical
//! per-step seconds on the new parallel fault driver for every worker
//! count (parallel ≡ sequential) and against the reference sequential
//! loop. Any divergence prints the first mismatching event or step.

mod common;

use h2::comm::{CommAlgo, CommMode};
use h2::costmodel::{GroupPlan, Schedule, Strategy};
use h2::elastic::FaultPlan;
use h2::hetero::{ChipKind, Cluster};
use h2::sim::reference::{
    simulate_iteration_reference_timeline, simulate_plan_with_faults_reference,
};
use h2::sim::{
    simulate_plan_with_faults, simulate_plan_with_faults_workers, ReshardStrategy, SimEngine,
    SimOptions,
};
use h2::topology::NicAssignment;
use h2::util::prop;

#[test]
fn engine_matches_reference_bit_for_bit() {
    prop::check(60, |rng| {
        let model = common::tiny_model();

        // 1–2 distinct chip kinds, node-aligned chip counts.
        let mut pool = [ChipKind::A, ChipKind::B, ChipKind::C];
        rng.shuffle(&mut pool);
        let n_kinds = rng.usize(1, 3);
        let kinds: Vec<(ChipKind, usize)> = pool[..n_kinds]
            .iter()
            .map(|&k| (k, *rng.choose(&[16usize, 32, 48])))
            .collect();
        let cluster = Cluster::new("diff", kinds);
        let groups = cluster.groups_by_memory_desc();

        let plans: Vec<GroupPlan> = (0..groups.len())
            .map(|_| {
                let s_pp = rng.usize(1, 4);
                let lps = rng.usize(1, 5);
                GroupPlan {
                    s_pp,
                    s_tp: *rng.choose(&[1usize, 2, 4]),
                    layers: s_pp * lps,
                    recompute: rng.f64() < 0.5,
                }
            })
            .collect();
        let schedule = *rng.choose(&[
            Schedule::OneF1B,
            Schedule::Interleaved { virtual_stages: 2 },
            Schedule::Interleaved { virtual_stages: 3 },
            Schedule::ZeroBubbleV,
        ]);
        let strategy = Strategy {
            s_ep: 1,
            s_dp: *rng.choose(&[1usize, 2, 4]),
            micro_batches: rng.usize(1, 11),
            schedule,
            comm_algo: *rng.choose(&CommAlgo::ALL),
            plans,
        };
        let opts = SimOptions {
            comm: *rng.choose(&[CommMode::TcpCpu, CommMode::RdmaCpu, CommMode::DeviceDirect]),
            reshard: *rng.choose(&[
                ReshardStrategy::NaiveP2p,
                ReshardStrategy::Broadcast,
                ReshardStrategy::SendRecvAllGather,
            ]),
            nic_assignment: *rng.choose(&[NicAssignment::Affinity, NicAssignment::NonAffinity]),
            fine_overlap: rng.f64() < 0.5,
        };
        let micro_tokens = *rng.choose(&[1024usize, 2048, 4096]);

        let mut eng = SimEngine::new(&model, &groups, &strategy, micro_tokens, &opts);
        let (eng_sim, eng_t) = eng.run_timeline();
        let (ref_sim, ref_t) = simulate_iteration_reference_timeline(
            &model, &groups, &strategy, micro_tokens, &opts,
        );

        if let Some(diff) = ref_t.diff(&eng_t) {
            return Err(format!("{schedule}: timeline diverged: {diff}"));
        }
        prop::assert_prop(
            eng_sim.iteration_seconds == ref_sim.iteration_seconds,
            format!(
                "{schedule}: iteration {} vs {}",
                eng_sim.iteration_seconds, ref_sim.iteration_seconds
            ),
        )?;
        prop::assert_prop(eng_sim.busy == ref_sim.busy, format!("{schedule}: busy"))?;
        prop::assert_prop(
            eng_sim.bubble_fraction == ref_sim.bubble_fraction,
            format!("{schedule}: bubble"),
        )?;
        prop::assert_prop(
            eng_sim.exposed_comm == ref_sim.exposed_comm,
            format!("{schedule}: exposed comm"),
        )?;

        // Re-running the warm engine must not drift either.
        let again = eng.run();
        prop::assert_prop(
            again.iteration_seconds == eng_sim.iteration_seconds,
            format!("{schedule}: warm re-run drifted"),
        )?;
        Ok(())
    });
}

#[test]
fn fault_path_matches_reference_and_parallel_matches_sequential() {
    prop::check(25, |rng| {
        let schedule = *rng.choose(&[
            Schedule::OneF1B,
            Schedule::Interleaved { virtual_stages: 2 },
            Schedule::ZeroBubbleV,
        ]);
        let algo = *rng.choose(&CommAlgo::ALL);
        let plan = common::two_stage_mixed_vendor_plan(schedule, algo);
        let steps = rng.usize(4, 13);
        let faults = FaultPlan::generate(rng.next_u64(), steps, 2, rng.f64() < 0.5);

        let default = simulate_plan_with_faults(&plan, &faults, steps)
            .map_err(|e| e.to_string())?;
        let seq = simulate_plan_with_faults_workers(&plan, &faults, steps, 1)
            .map_err(|e| e.to_string())?;
        let par = simulate_plan_with_faults_workers(&plan, &faults, steps, 4)
            .map_err(|e| e.to_string())?;
        let reference = simulate_plan_with_faults_reference(&plan, &faults, steps)
            .map_err(|e| e.to_string())?;

        for (label, r) in [("default", &default), ("workers=1", &seq), ("workers=4", &par)] {
            prop::assert_prop(
                r.halted_at == reference.halted_at,
                format!("{schedule}: {label} halted_at {:?} vs {:?}",
                        r.halted_at, reference.halted_at),
            )?;
            prop::assert_prop(
                r.step_seconds == reference.step_seconds,
                format!("{schedule}: {label} step seconds diverged: {:?} vs {:?}",
                        r.step_seconds, reference.step_seconds),
            )?;
            prop::assert_prop(
                r.total_seconds == reference.total_seconds,
                format!("{schedule}: {label} total {} vs {}",
                        r.total_seconds, reference.total_seconds),
            )?;
        }
        Ok(())
    });
}
