//! End-to-end elastic scenario on the 2-stage mixed-vendor fixture: a
//! seeded fault plan kills one Chip B node at step 3 of 6. The run must
//! drain at the step boundary, the monitor must raise a debounced `Dead`
//! event, `auto::replan` must produce a valid v4 plan excluding the dead
//! chips, and the hot-swap resume must be bit-identical to
//! restart-from-checkpoint on the reduced cluster — with the recovery
//! path beating the restart path by the pinned margin in all three
//! evaluators (cost model, simulator, virtual coordinator).

mod common;

use common::two_stage_mixed_vendor_plan as fixture;
use h2::auto::{replan, search, ClusterDelta, ReplanOptions, SearchConfig};
use h2::comm::CommAlgo;
use h2::coordinator::{train_virtual, VirtualOptions};
use h2::costmodel::{evaluate_plan, ProfileCache, Schedule};
use h2::elastic::{
    migrate_state, swap_compatible, ElasticEvent, FaultEvent, FaultKind, FaultPlan, MonitorConfig,
    RecoveryTimeline, StepMonitor,
};
use h2::hetero::ChipKind;
use h2::plan::ExecutionPlan;
use h2::sim::{simulate_plan, simulate_plan_with_faults};

const STEPS: usize = 6;
const KILL_STEP: usize = 3;

/// The seeded fault script: one node of stage 1's chip group (Chip B,
/// 8 chips/node) dies at the start of step 3.
fn kill_one_b_node() -> FaultPlan {
    FaultPlan {
        seed: 0xE1A5,
        events: vec![FaultEvent {
            step: KILL_STEP,
            stage: 1,
            kind: FaultKind::ChipDeath { nodes: 1 },
        }],
    }
}

fn b_chips(plan: &ExecutionPlan) -> usize {
    plan.cluster
        .groups
        .iter()
        .filter(|g| g.spec.kind == ChipKind::B)
        .map(|g| g.n_chips)
        .sum()
}

#[test]
fn kill_a_chip_at_step_n_recovers_bit_identically_and_beats_restart() {
    let incumbent = fixture(Schedule::OneF1B, CommAlgo::Ring);
    let faults = kill_one_b_node();

    // Reference: the uninterrupted 6-step run.
    let healthy =
        train_virtual(&incumbent, &VirtualOptions { steps: STEPS, ..Default::default() }).unwrap();

    // Phase A — the same run under the fault plan, checkpointing every
    // step: it must drain at the step-3 boundary with steps 0..3 done and
    // bit-identical to the healthy prefix.
    let old_dir = std::env::temp_dir().join("h2_elastic_e2e_old");
    let _ = std::fs::remove_dir_all(&old_dir);
    let halted = train_virtual(
        &incumbent,
        &VirtualOptions {
            steps: STEPS,
            checkpoint_dir: Some(old_dir.clone()),
            checkpoint_every: 1,
            faults: Some(faults.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(halted.halted_at, Some(KILL_STEP));
    assert_eq!(halted.losses, healthy.losses[..KILL_STEP], "pre-death steps diverged");

    // The simulator consumes the same script and halts at the same step.
    let sim_faulty = simulate_plan_with_faults(&incumbent, &faults, STEPS).unwrap();
    assert_eq!(sim_faulty.halted_at, Some(KILL_STEP));
    assert_eq!(sim_faulty.step_seconds.len(), KILL_STEP);

    // Detection — the dead replica's missed heartbeats fire a typed
    // `Dead` event only once the debounce window closes; the healthy
    // replica on stage 0 stays silent throughout.
    let cfg = MonitorConfig::default();
    let mut monitor = StepMonitor::for_plan(&incumbent).unwrap();
    assert_eq!(monitor.stages(), 2);
    let mut event = None;
    for _ in 0..cfg.debounce {
        assert_eq!(event, None, "event fired before the debounce window closed");
        assert_eq!(monitor.observe(0, 0, Some(0.0)), None);
        event = monitor.observe(1, 0, None);
    }
    assert_eq!(event, Some(ElasticEvent::Dead { stage: 1, dp_rank: 0 }));

    // Re-plan — exclude the dead node's 8 chips. The pipeline-preserving
    // mode halves stage 1's TP (16 → 8 chips at s_tp 2), keeps every
    // surviving chip busy, and bumps the plan epoch.
    let cache = ProfileCache::new();
    let outcome = replan(
        &incumbent,
        &ClusterDelta::exclude(ChipKind::B, 8),
        &cache,
        &ReplanOptions::default(),
    )
    .unwrap();
    assert!(outcome.changed);
    assert_eq!(outcome.plan.plan_epoch, incumbent.plan_epoch + 1);
    assert_eq!(outcome.idled_chips, 0);
    assert!(outcome.plan.validate().is_ok(), "replanned plan must validate");
    assert_eq!(b_chips(&outcome.plan), 8, "dead chips must leave the cluster");
    assert_eq!(outcome.plan.strategy.plans[1].s_tp, 2);
    swap_compatible(&incumbent, &outcome.plan).unwrap();

    // A second replan over the now-warm cache re-profiles nothing.
    let rerun = replan(
        &incumbent,
        &ClusterDelta::exclude(ChipKind::B, 8),
        &cache,
        &ReplanOptions::default(),
    )
    .unwrap();
    assert_eq!(rerun.plan, outcome.plan, "replan must be deterministic");
    assert_eq!(rerun.cache_misses, 0, "warm cache must serve every profile");
    assert!(rerun.cache_hits > 0);

    // Hot swap — migrate the step-3 checkpoint into the new plan's stage
    // layout. Layer ownership is unchanged (only TP width shrank), so the
    // diff migration ships zero layers.
    let new_dir = std::env::temp_dir().join("h2_elastic_e2e_new");
    let _ = std::fs::remove_dir_all(&new_dir);
    let migration = migrate_state(&incumbent, &outcome.plan, &old_dir, &new_dir).unwrap();
    assert!(migration.moves.is_empty(), "TP-only shrink must not move layers");

    // Resume from the migrated checkpoint on the new plan…
    let resumed = train_virtual(
        &outcome.plan,
        &VirtualOptions { steps: STEPS, resume_from: Some(new_dir), ..Default::default() },
    )
    .unwrap();
    assert_eq!(resumed.start_step, KILL_STEP);
    // …and the restart baseline: restart-from-checkpoint reads the
    // original step-3 checkpoint directly on the reduced cluster.
    let restarted = train_virtual(
        &outcome.plan,
        &VirtualOptions { steps: STEPS, resume_from: Some(old_dir), ..Default::default() },
    )
    .unwrap();
    assert_eq!(resumed.losses, restarted.losses, "hot swap diverged from restart");
    assert_eq!(resumed.final_params, restarted.final_params, "hot-swap params diverged");
    // The virtual numerics are TP-invariant, so the post-swap trajectory
    // also tracks the uninterrupted run bit for bit.
    assert_eq!(resumed.losses, healthy.losses[KILL_STEP..]);
    assert_eq!(resumed.final_params, healthy.final_params);

    // Three-evaluator parity on the replanned plan: the new plan is a
    // first-class citizen of the parity contract, not a special case.
    let coord = train_virtual(&outcome.plan, &VirtualOptions { steps: 1, ..Default::default() })
        .unwrap()
        .step_seconds;
    let sim = simulate_plan(&outcome.plan).iteration_seconds;
    let cm = evaluate_plan(&outcome.plan).iteration_seconds;
    let rel_sim = (coord - sim).abs() / sim;
    assert!(rel_sim < 0.10, "coordinator {coord} vs simulator {sim} (rel {rel_sim:.3})");
    let rel_cm = (coord - cm).abs() / cm;
    assert!(rel_cm < 0.5, "coordinator {coord} vs cost model {cm} (rel {rel_cm:.3})");

    // Recovery must beat restart in all three evaluators. Drain and
    // detection are paid on both sides, so the pinned 2x margin is
    // asserted on the parts that differ: warm re-plan + diff migration
    // vs cold search + full-state restore.
    let t0 = std::time::Instant::now();
    search(
        &incumbent.model,
        &outcome.plan.cluster,
        incumbent.gbs_tokens,
        &SearchConfig::pinned(Schedule::OneF1B),
    )
    .unwrap();
    let search_seconds = t0.elapsed().as_secs_f64();
    for (name, step_seconds) in
        [("cost model", cm), ("simulator", sim), ("virtual coordinator", coord)]
    {
        let tl = RecoveryTimeline::new(
            &incumbent,
            &outcome.plan,
            step_seconds,
            cfg.debounce,
            outcome.elapsed_seconds,
            search_seconds,
        )
        .unwrap();
        assert!(
            tl.recovery_seconds() < tl.restart_seconds(),
            "{name}: recovery {} !< restart {}",
            tl.recovery_seconds(),
            tl.restart_seconds()
        );
        assert!(
            tl.replan_seconds + tl.migrate_seconds
                < 0.5 * (tl.search_seconds + tl.restore_seconds),
            "{name}: replan {} + migrate {} lost the 2x margin to search {} + restore {}",
            tl.replan_seconds,
            tl.migrate_seconds,
            tl.search_seconds,
            tl.restore_seconds
        );
    }
}

/// A transient slowdown that heals before the reaction grace window
/// closes must cancel the pending re-plan: the `Recovered` event is the
/// cancellation signal, driven end-to-end through `train_virtual`'s
/// heartbeat stream rather than hand-fed observations.
#[test]
fn recovered_event_cancels_a_pending_straggler_reaction() {
    let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
    let cfg = MonitorConfig::default();
    // Stage 1 runs 2x slow on steps 1..3, then heals. 2.0 clears the
    // default 1.3 straggler threshold with margin.
    let faults = FaultPlan {
        seed: 7,
        events: vec![
            FaultEvent { step: 1, stage: 1, kind: FaultKind::Slowdown { factor: 2.0 } },
            FaultEvent { step: 3, stage: 1, kind: FaultKind::Recover },
        ],
    };
    let r = train_virtual(
        &plan,
        &VirtualOptions { steps: STEPS, faults: Some(faults), ..Default::default() },
    )
    .unwrap();

    // Reaction policy under test: a Straggler arms a re-plan after a
    // grace window of debounce + 1 further steps; a Recovered event that
    // arrives first cancels it.
    let mut monitor = StepMonitor::for_plan(&plan).unwrap();
    let mut pending_replan_at: Option<usize> = None;
    let mut straggler_step = None;
    let mut recovered_step = None;
    let mut replans = 0usize;
    for step in 0..STEPS {
        if pending_replan_at == Some(step) {
            replans += 1;
            pending_replan_at = None;
        }
        for stage in 0..monitor.stages() {
            let obs = r.stage_compute_seconds[stage][step];
            match monitor.observe(stage, 0, Some(obs)) {
                Some(ElasticEvent::Straggler { stage: s, .. }) => {
                    assert_eq!(s, 1, "only the faulty stage may straggle");
                    straggler_step = Some(step);
                    pending_replan_at = Some(step + cfg.debounce + 1);
                }
                Some(ElasticEvent::Recovered { stage: s, .. }) => {
                    assert_eq!(s, 1);
                    recovered_step = Some(step);
                    pending_replan_at = None;
                }
                Some(other) => panic!("unexpected event at step {step}: {other:?}"),
                None => {}
            }
        }
    }
    // Slow steps 1, 2 → Straggler fires at step 2 (debounce 2); healthy
    // steps 3, 4 → Recovered at step 4, one step before the armed
    // re-plan at step 5 would have triggered.
    assert_eq!(straggler_step, Some(1 + cfg.debounce - 1));
    assert_eq!(recovered_step, Some(3 + cfg.debounce - 1));
    assert_eq!(replans, 0, "the healed straggler must not trigger a re-plan");
    assert_eq!(pending_replan_at, None);
}

/// A NIC degradation is invisible in the compute heartbeat (the honest
/// monitoring gap) but observable in the full-step stream — and the
/// straggler debounce boundary is exact on that stream.
#[test]
fn nic_degrade_is_observed_at_exactly_the_debounce_boundary() {
    const RUN: usize = 4;
    let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
    let healthy =
        train_virtual(&plan, &VirtualOptions { steps: RUN, ..Default::default() }).unwrap();
    let faults = FaultPlan {
        seed: 8,
        events: vec![FaultEvent {
            step: 0,
            stage: 1,
            kind: FaultKind::NicDegrade { factor: 3.0 },
        }],
    };
    let degraded = train_virtual(
        &plan,
        &VirtualOptions { steps: RUN, faults: Some(faults), ..Default::default() },
    )
    .unwrap();

    // Compute is untouched — bitwise — so a compute-fed monitor is blind.
    assert_eq!(degraded.stage_compute_seconds, healthy.stage_compute_seconds);
    let mut blind = StepMonitor::for_plan(&plan).unwrap();
    for step in 0..RUN {
        for stage in 0..blind.stages() {
            let obs = degraded.stage_compute_seconds[stage][step];
            assert_eq!(blind.observe(stage, 0, Some(obs)), None, "compute stream must be silent");
        }
    }

    // The full-step stream sees it: stage 1's exposed DP-sync slice is
    // 3x, stage 0's is untouched (bitwise).
    assert_eq!(degraded.stage_step_seconds[0], healthy.stage_step_seconds[0]);
    let ratio = degraded.stage_step_seconds[1][0] / healthy.stage_step_seconds[1][0];
    assert!(ratio > 1.0, "NIC degradation must stretch the full step: ratio {ratio}");

    // A monitor whose baseline is the healthy full-step time and whose
    // threshold sits just under the observed ratio fires on exactly the
    // debounce-th observation — and just above it, never.
    let expected: Vec<f64> =
        (0..2).map(|stage| healthy.stage_step_seconds[stage][0]).collect();
    let debounce = 2;
    let mut armed = StepMonitor::new(
        expected.clone(),
        1,
        MonitorConfig { straggler_factor: ratio * 0.999, debounce },
    );
    let mut fired_at = None;
    for step in 0..RUN {
        let e = armed.observe(1, 0, Some(degraded.stage_step_seconds[1][step]));
        if let Some(ev) = e {
            assert!(matches!(ev, ElasticEvent::Straggler { stage: 1, dp_rank: 0, .. }), "{ev:?}");
            assert_eq!(fired_at, None, "must fire exactly once");
            fired_at = Some(step);
        }
    }
    assert_eq!(fired_at, Some(debounce - 1), "fires on the debounce-th observation");

    let mut above = StepMonitor::new(
        expected,
        1,
        MonitorConfig { straggler_factor: ratio * 1.001, debounce },
    );
    for step in 0..RUN {
        let e = above.observe(1, 0, Some(degraded.stage_step_seconds[1][step]));
        assert_eq!(e, None, "a threshold above the ratio must stay silent");
    }
}
