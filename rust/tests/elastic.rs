//! End-to-end elastic scenario on the 2-stage mixed-vendor fixture: a
//! seeded fault plan kills one Chip B node at step 3 of 6. The run must
//! drain at the step boundary, the monitor must raise a debounced `Dead`
//! event, `auto::replan` must produce a valid v4 plan excluding the dead
//! chips, and the hot-swap resume must be bit-identical to
//! restart-from-checkpoint on the reduced cluster — with the recovery
//! path beating the restart path by the pinned margin in all three
//! evaluators (cost model, simulator, virtual coordinator).

mod common;

use common::two_stage_mixed_vendor_plan as fixture;
use h2::auto::{replan, search, ClusterDelta, ReplanOptions, SearchConfig};
use h2::comm::CommAlgo;
use h2::coordinator::{train_virtual, VirtualOptions};
use h2::costmodel::{evaluate_plan, ProfileCache, Schedule};
use h2::elastic::{
    migrate_state, swap_compatible, ElasticEvent, FaultEvent, FaultKind, FaultPlan, MonitorConfig,
    RecoveryTimeline, StepMonitor,
};
use h2::hetero::ChipKind;
use h2::plan::ExecutionPlan;
use h2::sim::{simulate_plan, simulate_plan_with_faults};

const STEPS: usize = 6;
const KILL_STEP: usize = 3;

/// The seeded fault script: one node of stage 1's chip group (Chip B,
/// 8 chips/node) dies at the start of step 3.
fn kill_one_b_node() -> FaultPlan {
    FaultPlan {
        seed: 0xE1A5,
        events: vec![FaultEvent {
            step: KILL_STEP,
            stage: 1,
            kind: FaultKind::ChipDeath { nodes: 1 },
        }],
    }
}

fn b_chips(plan: &ExecutionPlan) -> usize {
    plan.cluster
        .groups
        .iter()
        .filter(|g| g.spec.kind == ChipKind::B)
        .map(|g| g.n_chips)
        .sum()
}

#[test]
fn kill_a_chip_at_step_n_recovers_bit_identically_and_beats_restart() {
    let incumbent = fixture(Schedule::OneF1B, CommAlgo::Ring);
    let faults = kill_one_b_node();

    // Reference: the uninterrupted 6-step run.
    let healthy =
        train_virtual(&incumbent, &VirtualOptions { steps: STEPS, ..Default::default() }).unwrap();

    // Phase A — the same run under the fault plan, checkpointing every
    // step: it must drain at the step-3 boundary with steps 0..3 done and
    // bit-identical to the healthy prefix.
    let old_dir = std::env::temp_dir().join("h2_elastic_e2e_old");
    let _ = std::fs::remove_dir_all(&old_dir);
    let halted = train_virtual(
        &incumbent,
        &VirtualOptions {
            steps: STEPS,
            checkpoint_dir: Some(old_dir.clone()),
            checkpoint_every: 1,
            faults: Some(faults.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(halted.halted_at, Some(KILL_STEP));
    assert_eq!(halted.losses, healthy.losses[..KILL_STEP], "pre-death steps diverged");

    // The simulator consumes the same script and halts at the same step.
    let sim_faulty = simulate_plan_with_faults(&incumbent, &faults, STEPS).unwrap();
    assert_eq!(sim_faulty.halted_at, Some(KILL_STEP));
    assert_eq!(sim_faulty.step_seconds.len(), KILL_STEP);

    // Detection — the dead replica's missed heartbeats fire a typed
    // `Dead` event only once the debounce window closes; the healthy
    // replica on stage 0 stays silent throughout.
    let cfg = MonitorConfig::default();
    let mut monitor = StepMonitor::for_plan(&incumbent).unwrap();
    assert_eq!(monitor.stages(), 2);
    let mut event = None;
    for _ in 0..cfg.debounce {
        assert_eq!(event, None, "event fired before the debounce window closed");
        assert_eq!(monitor.observe(0, 0, Some(0.0)), None);
        event = monitor.observe(1, 0, None);
    }
    assert_eq!(event, Some(ElasticEvent::Dead { stage: 1, dp_rank: 0 }));

    // Re-plan — exclude the dead node's 8 chips. The pipeline-preserving
    // mode halves stage 1's TP (16 → 8 chips at s_tp 2), keeps every
    // surviving chip busy, and bumps the plan epoch.
    let cache = ProfileCache::new();
    let outcome = replan(
        &incumbent,
        &ClusterDelta::exclude(ChipKind::B, 8),
        &cache,
        &ReplanOptions::default(),
    )
    .unwrap();
    assert!(outcome.changed);
    assert_eq!(outcome.plan.plan_epoch, incumbent.plan_epoch + 1);
    assert_eq!(outcome.idled_chips, 0);
    assert!(outcome.plan.validate().is_ok(), "replanned plan must validate");
    assert_eq!(b_chips(&outcome.plan), 8, "dead chips must leave the cluster");
    assert_eq!(outcome.plan.strategy.plans[1].s_tp, 2);
    swap_compatible(&incumbent, &outcome.plan).unwrap();

    // A second replan over the now-warm cache re-profiles nothing.
    let rerun = replan(
        &incumbent,
        &ClusterDelta::exclude(ChipKind::B, 8),
        &cache,
        &ReplanOptions::default(),
    )
    .unwrap();
    assert_eq!(rerun.plan, outcome.plan, "replan must be deterministic");
    assert_eq!(rerun.cache_misses, 0, "warm cache must serve every profile");
    assert!(rerun.cache_hits > 0);

    // Hot swap — migrate the step-3 checkpoint into the new plan's stage
    // layout. Layer ownership is unchanged (only TP width shrank), so the
    // diff migration ships zero layers.
    let new_dir = std::env::temp_dir().join("h2_elastic_e2e_new");
    let _ = std::fs::remove_dir_all(&new_dir);
    let migration = migrate_state(&incumbent, &outcome.plan, &old_dir, &new_dir).unwrap();
    assert!(migration.moves.is_empty(), "TP-only shrink must not move layers");

    // Resume from the migrated checkpoint on the new plan…
    let resumed = train_virtual(
        &outcome.plan,
        &VirtualOptions { steps: STEPS, resume_from: Some(new_dir), ..Default::default() },
    )
    .unwrap();
    assert_eq!(resumed.start_step, KILL_STEP);
    // …and the restart baseline: restart-from-checkpoint reads the
    // original step-3 checkpoint directly on the reduced cluster.
    let restarted = train_virtual(
        &outcome.plan,
        &VirtualOptions { steps: STEPS, resume_from: Some(old_dir), ..Default::default() },
    )
    .unwrap();
    assert_eq!(resumed.losses, restarted.losses, "hot swap diverged from restart");
    assert_eq!(resumed.final_params, restarted.final_params, "hot-swap params diverged");
    // The virtual numerics are TP-invariant, so the post-swap trajectory
    // also tracks the uninterrupted run bit for bit.
    assert_eq!(resumed.losses, healthy.losses[KILL_STEP..]);
    assert_eq!(resumed.final_params, healthy.final_params);

    // Three-evaluator parity on the replanned plan: the new plan is a
    // first-class citizen of the parity contract, not a special case.
    let coord = train_virtual(&outcome.plan, &VirtualOptions { steps: 1, ..Default::default() })
        .unwrap()
        .step_seconds;
    let sim = simulate_plan(&outcome.plan).iteration_seconds;
    let cm = evaluate_plan(&outcome.plan).iteration_seconds;
    let rel_sim = (coord - sim).abs() / sim;
    assert!(rel_sim < 0.10, "coordinator {coord} vs simulator {sim} (rel {rel_sim:.3})");
    let rel_cm = (coord - cm).abs() / cm;
    assert!(rel_cm < 0.5, "coordinator {coord} vs cost model {cm} (rel {rel_cm:.3})");

    // Recovery must beat restart in all three evaluators. Drain and
    // detection are paid on both sides, so the pinned 2x margin is
    // asserted on the parts that differ: warm re-plan + diff migration
    // vs cold search + full-state restore.
    let t0 = std::time::Instant::now();
    search(
        &incumbent.model,
        &outcome.plan.cluster,
        incumbent.gbs_tokens,
        &SearchConfig::pinned(Schedule::OneF1B),
    )
    .unwrap();
    let search_seconds = t0.elapsed().as_secs_f64();
    for (name, step_seconds) in
        [("cost model", cm), ("simulator", sim), ("virtual coordinator", coord)]
    {
        let tl = RecoveryTimeline::new(
            &incumbent,
            &outcome.plan,
            step_seconds,
            cfg.debounce,
            outcome.elapsed_seconds,
            search_seconds,
        )
        .unwrap();
        assert!(
            tl.recovery_seconds() < tl.restart_seconds(),
            "{name}: recovery {} !< restart {}",
            tl.recovery_seconds(),
            tl.restart_seconds()
        );
        assert!(
            tl.replan_seconds + tl.migrate_seconds
                < 0.5 * (tl.search_seconds + tl.restore_seconds),
            "{name}: replan {} + migrate {} lost the 2x margin to search {} + restore {}",
            tl.replan_seconds,
            tl.migrate_seconds,
            tl.search_seconds,
            tl.restore_seconds
        );
    }
}
