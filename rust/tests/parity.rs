//! Three-evaluator parity: one `ExecutionPlan`, three independent
//! machines — the §4.3.2 closed-form cost model
//! (`costmodel::evaluate_plan`), the discrete-event HeteroPP simulator
//! (`sim::simulate_plan`), and the coordinator's plan-driven virtual
//! evaluator (`coordinator::train_virtual`) — must agree on what the plan
//! costs, for every (schedule × comm-algo) pair, on a 2-stage
//! mixed-vendor fixture.
//!
//! The coordinator is the sharpest check: it *executes* the plan (real op
//! orders over a thread fabric, real collectives over rank buffers) and
//! only its clock is modeled. 1F1B and interleaved replay exactly the
//! simulator's issue orders, so their step seconds must track the
//! simulator tightly; the zero-bubble schedule freezes unit-time greedy
//! decisions into a static order, so it gets a looser band. The cost
//! model folds schedules into a bubble coefficient and gets the loosest.

mod common;

use common::two_stage_mixed_vendor_plan as fixture;
use h2::comm::CommAlgo;
use h2::coordinator::{train_virtual, VirtualOptions};
use h2::costmodel::{evaluate_plan, Schedule};
use h2::plan::ExecutionPlan;
use h2::sim::simulate_plan;

/// One-step virtual run: the clock starts at zero and ends after exactly
/// one pipeline fill + drain + update, the same window the simulator and
/// cost model price.
fn virtual_step(plan: &ExecutionPlan) -> (f64, f64) {
    let r = train_virtual(plan, &VirtualOptions { steps: 1, ..Default::default() }).unwrap();
    (r.step_seconds, r.comm_seconds)
}

#[test]
fn three_evaluators_agree_on_every_schedule_x_comm_algo() {
    for schedule in Schedule::SEARCH_SPACE {
        // The static zero-bubble order is a unit-time freeze of the
        // simulator's duration-aware greedy executor: same work, slightly
        // different slotting. 1F1B/interleaved replay identical orders.
        let sim_tol = match schedule {
            Schedule::ZeroBubbleV => 0.30,
            _ => 0.10,
        };
        for comm_algo in CommAlgo::ALL {
            let plan = fixture(schedule, comm_algo);
            let (coord, _) = virtual_step(&plan);
            let sim = simulate_plan(&plan).iteration_seconds;
            let cm = evaluate_plan(&plan).iteration_seconds;

            let rel_sim = (coord - sim).abs() / sim;
            assert!(
                rel_sim < sim_tol,
                "{schedule}/{comm_algo}: coordinator {coord} vs simulator {sim} \
                 (rel {rel_sim:.3} > {sim_tol})"
            );
            let rel_cm = (coord - cm).abs() / cm;
            assert!(
                rel_cm < 0.5,
                "{schedule}/{comm_algo}: coordinator {coord} vs cost model {cm} \
                 (rel {rel_cm:.3})"
            );
        }
    }
}

#[test]
fn coordinator_comm_ordering_matches_the_simulator() {
    // Acceptance: hierarchical must report lower virtual comm seconds
    // than the flat ring on the node-crossing fixture, and the simulator
    // must order the same way on iteration time.
    for schedule in Schedule::SEARCH_SPACE {
        let ring_plan = fixture(schedule, CommAlgo::Ring);
        let hier_plan = fixture(schedule, CommAlgo::Hierarchical);
        let (ring_step, ring_comm) = virtual_step(&ring_plan);
        let (hier_step, hier_comm) = virtual_step(&hier_plan);
        assert!(
            hier_comm < ring_comm,
            "{schedule}: hierarchical comm {hier_comm} !< ring comm {ring_comm}"
        );
        assert!(
            hier_step <= ring_step,
            "{schedule}: hierarchical step {hier_step} !<= ring step {ring_step}"
        );
        let sim_ring = simulate_plan(&ring_plan).iteration_seconds;
        let sim_hier = simulate_plan(&hier_plan).iteration_seconds;
        assert!(
            sim_hier < sim_ring,
            "{schedule}: simulator disagrees — hier {sim_hier} !< ring {sim_ring}"
        );
    }
}

#[test]
fn auto_never_loses_to_any_concrete_algorithm() {
    let (auto_step, _) = virtual_step(&fixture(Schedule::OneF1B, CommAlgo::Auto));
    for algo in CommAlgo::CONCRETE {
        let (step, _) = virtual_step(&fixture(Schedule::OneF1B, algo));
        // Auto resolves per stage to the closed-form argmin; executed
        // seconds track the closed form to rounding.
        assert!(
            auto_step <= step * 1.0001,
            "auto {auto_step} lost to {algo} {step}"
        );
    }
}

#[test]
fn gradients_are_bit_identical_across_all_five_comm_algos() {
    // The synthetic model keeps gradients on the 2^-8 dyadic grid, so f32
    // reduction is exact in any association: every collective algorithm
    // must yield bit-identical parameters after 3 steps.
    let opts = VirtualOptions { steps: 3, ..Default::default() };
    let reference = train_virtual(&fixture(Schedule::OneF1B, CommAlgo::Ring), &opts).unwrap();
    assert_eq!(reference.final_params.len(), 2);
    assert!(reference.final_params.iter().all(|p| !p.is_empty()));
    for algo in CommAlgo::ALL {
        let run = train_virtual(&fixture(Schedule::OneF1B, algo), &opts).unwrap();
        for (s, (a, b)) in run.final_params.iter().zip(&reference.final_params).enumerate() {
            assert_eq!(a.len(), b.len(), "{algo} stage {s}");
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{algo}: param {i} of stage {s} diverged ({x} vs {y})"
                );
            }
        }
        // Losses ride on the forward pass only — identical too.
        assert_eq!(run.losses, reference.losses, "{algo}");
    }
}

#[test]
fn zero_bubble_reorders_without_changing_numerics() {
    // ZB-V splits backward into B/W phases and reorders execution, but
    // computes exactly what 1F1B computes (same chunking): the loss
    // trajectory and final parameters must match bit-for-bit. (The
    // interleaved schedule re-chunks the synthetic model into `v` weight
    // vectors per stage, so its numerics legitimately differ.)
    let opts = VirtualOptions { steps: 3, ..Default::default() };
    let f1b = train_virtual(&fixture(Schedule::OneF1B, CommAlgo::Ring), &opts).unwrap();
    let zbv = train_virtual(&fixture(Schedule::ZeroBubbleV, CommAlgo::Ring), &opts).unwrap();
    assert_eq!(zbv.losses, f1b.losses, "zbv losses diverged from 1f1b");
    assert_eq!(zbv.final_params, f1b.final_params, "zbv params diverged from 1f1b");
}
