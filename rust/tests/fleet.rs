//! Fleet-scheduler integration tests: the determinism contract (same
//! trace + policy ⇒ bit-identical [`FleetTimeline`] JSON, for any worker
//! count), the policy contrast the pinned trace exists to show
//! (priority-with-backfill beats FIFO on p99 job wait), and the CLI
//! round-trip of a trace file through `h2 fleet`.

use std::path::PathBuf;
use std::process::Command;

use h2::fleet::{
    fleet_search_config, run, ClusterFaultPlan, FaultResponse, FleetEventKind, FleetOptions,
    FleetTimeline, FreePool, JobModel, JobSpec, JobTrace, PlaceOutcome, Policy, Scheduler,
};
use h2::hetero::{spec, ChipKind, Cluster};

/// The two-vendor lab cluster the in-process tests run on: big enough
/// that the pinned trace's whole-cluster jobs are searchable and its
/// 64-chip jobs leave contention, small enough to keep the inner
/// HeteroAuto solves fast.
fn lab() -> Cluster {
    Cluster::new("lab", vec![(ChipKind::A, 64), (ChipKind::B, 64)])
}

fn run_policy(cluster: &Cluster, trace: &JobTrace, policy: Policy, workers: usize) -> FleetTimeline {
    let opts = FleetOptions { policy, workers, ..FleetOptions::default() };
    run(cluster, trace, &opts).expect("fleet run failed")
}

#[test]
fn pinned_trace_contrast_priority_beats_fifo_on_p99_wait() {
    let cluster = lab();
    let trace = JobTrace::pinned(cluster.total_chips());

    let fifo = run_policy(&cluster, &trace, Policy::Fifo, 1);
    let pri = run_policy(&cluster, &trace, Policy::PriorityBackfill, 1);

    // Both policies finish the whole queue on this cluster.
    for tl in [&fifo, &pri] {
        assert_eq!(tl.metrics.jobs, trace.jobs.len());
        assert_eq!(tl.metrics.completed, trace.jobs.len(), "{:?}", tl.metrics);
        assert_eq!(tl.metrics.rejected, 0);
        assert!(tl.metrics.utilization > 0.0 && tl.metrics.utilization <= 1.0 + 1e-9);
    }

    // The contrast the trace is built for: under FIFO the second
    // whole-cluster job blocks the burst of small high-priority jobs, so
    // its long runtime lands in their waits; under priority-with-backfill
    // they overtake it. p99 wait must fall — structurally, not by luck.
    assert!(
        pri.metrics.p99_wait_seconds < fifo.metrics.p99_wait_seconds,
        "priority p99 {} should beat fifo p99 {}",
        pri.metrics.p99_wait_seconds,
        fifo.metrics.p99_wait_seconds
    );
    assert_ne!(fifo.metrics, pri.metrics, "policies must be distinguishable");

    // Event-stream sanity on both timelines.
    for tl in [&fifo, &pri] {
        let mut prev = 0.0f64;
        for e in &tl.events {
            assert!(e.t_seconds >= prev, "events out of order: {:?}", tl.events);
            prev = e.t_seconds;
            if let FleetEventKind::Resize { freed_chips, migrate_seconds, .. } = e.kind {
                assert!(freed_chips > 0);
                assert!(migrate_seconds >= 0.0);
            }
        }
        for j in &tl.jobs {
            let w = j.wait_seconds.expect("all jobs completed");
            assert!(w >= 0.0, "negative wait for job {}", j.id);
            assert!(j.finish_seconds.expect("finished") >= j.arrival_seconds + w);
        }
    }
}

#[test]
fn timeline_is_bit_identical_across_repeats_and_worker_counts() {
    let cluster = lab();
    let trace = JobTrace::pinned(cluster.total_chips());

    // Repeats (fresh Scheduler, fresh ProfileCache each time)...
    let a = run_policy(&cluster, &trace, Policy::PriorityBackfill, 1);
    let b = run_policy(&cluster, &trace, Policy::PriorityBackfill, 1);
    assert_eq!(a.to_json_string(), b.to_json_string(), "repeat determinism");

    // ...and worker counts are purely wall-clock knobs.
    let c = run_policy(&cluster, &trace, Policy::PriorityBackfill, 4);
    assert_eq!(a.to_json_string(), c.to_json_string(), "worker-count invariance");
}

#[test]
fn generated_trace_runs_deterministically_end_to_end() {
    // One vendor, whole-cluster jobs: the generator path (Poisson
    // arrivals, bursts) through the full loop, twice.
    let cluster = Cluster::new("solo", vec![(ChipKind::A, 64)]);
    let trace = JobTrace::generate(7, 5, cluster.total_chips());
    assert_eq!(trace.jobs.len(), 5);

    let a = run_policy(&cluster, &trace, Policy::Fifo, 0);
    let b = run_policy(&cluster, &trace, Policy::Fifo, 0);
    assert_eq!(a.to_json_string(), b.to_json_string());
    assert_eq!(a.metrics.completed + a.metrics.rejected, 5);
    // Whole-node allocations only, ever.
    let node = spec(ChipKind::A).chips_per_node;
    for j in &a.jobs {
        assert_eq!(j.chips % node, 0, "ragged allocation for job {}", j.id);
    }
}

#[test]
fn failed_preemption_shrink_leaves_the_free_pool_untouched() {
    // A victim whose only chip group is a single node is not
    // swap-compatible with any shrink: `try_shrink` must keep at least
    // one node per group, so it can never free chips here — and a
    // placement round built on that failed shrink must leave the
    // `FreePool` exactly as it was.
    let cluster = Cluster::new("one-node", vec![(ChipKind::A, 16)]);
    let sched = Scheduler::new(Policy::PriorityBackfill, fleet_search_config());
    let mut pool = FreePool::new(&cluster);
    assert_eq!(pool.total(), cluster.total_chips());

    let victim_job = JobSpec {
        id: 0,
        model: JobModel::H20B,
        gbs_tokens: 128 * 4096,
        priority: 0,
        arrival_step: 0,
        min_chips: 16,
        max_chips: 16,
        steps: 10,
    };
    let PlaceOutcome::Placed(victim) = sched.try_place(&victim_job, &mut pool) else {
        panic!("victim placement failed on an idle one-node cluster");
    };
    // Chip accounting after the take: pool + held allocation = cluster.
    assert_eq!(victim.chips, 16);
    assert_eq!(pool.total() + victim.chips, cluster.total_chips());
    let snapshot = pool.clone();

    // A higher-priority arrival needs a whole node; the only victim
    // cannot shed one and survive, so the shrink must fail...
    let need = 16;
    assert!(
        sched.try_shrink(&victim.plan, 1.0, need).is_none(),
        "a one-node victim must never shrink"
    );
    // ...and the pool is bit-for-bit what it was before the attempt.
    assert_eq!(pool, snapshot);
    assert_eq!(pool.total() + victim.chips, cluster.total_chips());

    // The round then resolves to NoCapacity for the contender — which
    // also must not touch the pool.
    let contender = JobSpec { id: 1, priority: 3, arrival_step: 1, ..victim_job.clone() };
    assert!(matches!(sched.try_place(&contender, &mut pool), PlaceOutcome::NoCapacity));
    assert_eq!(pool, snapshot);

    // Releasing the victim restores the idle pool exactly.
    pool.release(&victim.alloc);
    assert_eq!(pool, FreePool::new(&cluster));
}

// ---------------------------------------------------------------------
// Cluster faults: the graceful-degradation cascade end to end.

#[test]
fn cluster_faults_cascade_recovers_in_place_requeues_and_beats_restart() {
    let cluster = lab();
    let trace = JobTrace::pinned(cluster.total_chips());
    // A 10-step checkpoint grid gives the requeued job real recompute to
    // pay, so the cascade-vs-restart contrast has room to show.
    let base = FleetOptions {
        policy: Policy::Fifo,
        workers: 1,
        checkpoint_every: 10,
        ..FleetOptions::default()
    };
    let healthy = run(&cluster, &trace, &base).expect("healthy run");
    assert_eq!(healthy.metrics.completed, trace.jobs.len());
    assert_eq!(healthy.metrics.faults, 0);
    assert_eq!(healthy.metrics.recomputed_steps, 0);
    // A healthy run wastes nothing: goodput equals utilization (up to fp
    // accumulation order).
    assert!(
        (healthy.metrics.goodput_fraction - healthy.metrics.utilization).abs() < 1e-9,
        "healthy goodput {} != utilization {}",
        healthy.metrics.goodput_fraction,
        healthy.metrics.utilization
    );

    let faults = ClusterFaultPlan::pinned_for(&cluster, &healthy).expect("pinned fault plan");
    let cascade_opts = FleetOptions { faults: Some(faults.clone()), ..base.clone() };
    let cascade = run(&cluster, &trace, &cascade_opts).expect("cascade run");

    // Every job still completes under the cascade...
    assert_eq!(cascade.metrics.completed, trace.jobs.len(), "{:?}", cascade.metrics);
    assert_eq!(cascade.metrics.rejected, 0);
    assert!(cascade.metrics.faults > 0);
    assert!(cascade.metrics.recovery_seconds_total > 0.0);
    assert!(cascade.metrics.goodput_fraction > 0.0);
    assert!(
        cascade.metrics.goodput_fraction < cascade.metrics.utilization,
        "faulty goodput must fall below utilization"
    );

    // ...but along the two distinct cascade paths the pinned plan was
    // authored for: job 0 loses one node and recovers *in place* (replan
    // or fault-shrink, never a requeue); job 1 loses a whole chip group
    // and can only requeue from its checkpoint.
    let job0: Vec<_> = cascade.events.iter().filter(|e| e.job == 0).collect();
    assert!(
        job0.iter().any(|e| matches!(
            e.kind,
            FleetEventKind::Replan { .. } | FleetEventKind::FaultShrink { .. }
        )),
        "job 0 must recover in place: {job0:?}"
    );
    assert!(
        !job0.iter().any(|e| matches!(e.kind, FleetEventKind::Requeue { .. })),
        "job 0 must not requeue: {job0:?}"
    );
    let job1: Vec<_> = cascade.events.iter().filter(|e| e.job == 1).collect();
    assert!(
        job1.iter().any(|e| matches!(e.kind, FleetEventKind::Requeue { .. })),
        "job 1 must requeue from checkpoint: {job1:?}"
    );
    assert!(cascade.metrics.recomputed_steps > 0, "the requeue rolls back steps");

    // Determinism: bit-identical timelines across repeats and worker
    // counts, faults included.
    let again = run(&cluster, &trace, &cascade_opts).expect("repeat");
    assert_eq!(cascade.to_json_string(), again.to_json_string(), "repeat determinism");
    let wide = run(
        &cluster,
        &trace,
        &FleetOptions { workers: 4, ..cascade_opts.clone() },
    )
    .expect("4-worker run");
    assert_eq!(cascade.to_json_string(), wide.to_json_string(), "worker-count invariance");

    // The cascade must beat the restart-every-victim baseline by a real
    // margin on goodput and finish sooner: that gap is what the in-place
    // rungs exist to buy.
    let restart = run(
        &cluster,
        &trace,
        &FleetOptions {
            faults: Some(faults),
            response: FaultResponse::RestartAlways,
            ..base
        },
    )
    .expect("restart baseline");
    assert_eq!(restart.metrics.completed, trace.jobs.len(), "{:?}", restart.metrics);
    assert!(
        cascade.metrics.goodput_fraction >= restart.metrics.goodput_fraction + 0.02,
        "cascade goodput {} must beat restart goodput {} by ≥ 0.02",
        cascade.metrics.goodput_fraction,
        restart.metrics.goodput_fraction
    );
    assert!(
        cascade.metrics.makespan_seconds < restart.metrics.makespan_seconds,
        "cascade makespan {} must beat restart makespan {}",
        cascade.metrics.makespan_seconds,
        restart.metrics.makespan_seconds
    );
    assert!(
        restart.metrics.recomputed_steps > cascade.metrics.recomputed_steps,
        "restarting every victim must recompute more: restart {} vs cascade {}",
        restart.metrics.recomputed_steps,
        cascade.metrics.recomputed_steps
    );
}

#[test]
fn generated_cluster_faults_run_deterministically() {
    // The seeded generator path end to end: degradations, one node
    // death, recoveries — same seed, same timeline, and dead capacity
    // returns to the pool on recovery.
    let cluster = Cluster::new("solo", vec![(ChipKind::A, 64)]);
    let trace = JobTrace::generate(7, 5, cluster.total_chips());
    let faults = ClusterFaultPlan::generate(11, &cluster, trace.horizon_seconds());
    let opts = FleetOptions { faults: Some(faults), workers: 1, ..FleetOptions::default() };
    let a = run(&cluster, &trace, &opts).expect("faulty generated run");
    let b = run(&cluster, &trace, &opts).expect("repeat");
    assert_eq!(a.to_json_string(), b.to_json_string());
    assert!(a.metrics.faults > 0);
    assert_eq!(
        a.metrics.dead_chips, 0,
        "the generated plan recovers its one death before the horizon"
    );
}

#[test]
fn oversized_jobs_are_rejected_up_front() {
    let cluster = Cluster::new("solo", vec![(ChipKind::A, 64)]);
    let mut trace = JobTrace::pinned(64);
    trace.jobs[0].min_chips = 128; // cluster only has 64
    trace.jobs[0].max_chips = 128;
    let err = run(&cluster, &trace, &FleetOptions::default()).unwrap_err();
    assert!(err.to_string().contains("128"), "unexpected error: {err}");
}

// ---------------------------------------------------------------------
// CLI: `h2 fleet` round-trips a trace file.

fn h2_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_h2"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("h2_fleet_tests").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawning h2");
    assert!(
        out.status.success(),
        "h2 {:?} failed:\nstdout: {}\nstderr: {}",
        cmd.get_args().collect::<Vec<_>>(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

/// A machine-readable `<prefix> <value>` line from stdout.
fn parse_line<'a>(stdout: &'a str, prefix: &str) -> &'a str {
    stdout
        .lines()
        .find_map(|l| l.strip_prefix(prefix))
        .unwrap_or_else(|| panic!("no `{prefix}` line in:\n{stdout}"))
        .trim()
}

#[test]
fn fleet_cli_round_trips_a_trace_file() {
    let dir = tmp_dir("roundtrip");
    let trace_path = dir.join("trace.json");
    let trace_path = trace_path.to_str().unwrap();
    let out_a = dir.join("a.json");
    let out_a = out_a.to_str().unwrap();
    let out_b = dir.join("b.json");
    let out_b = out_b.to_str().unwrap();

    // Generate from a seed, emitting both the trace and the timeline.
    let stdout = run_ok(h2_bin().args([
        "fleet", "--cluster", "A=64", "--trace", "7", "--jobs", "4",
        "--emit-trace", trace_path, "--out", out_a,
    ]));
    assert_eq!(parse_line(&stdout, "fleet_policy "), "fifo");
    assert_eq!(parse_line(&stdout, "fleet_jobs "), "4");
    let p99_a = parse_line(&stdout, "fleet_p99_wait_seconds ").to_string();

    // Replaying the emitted trace file reproduces the timeline
    // bit-for-bit — trace JSON is a lossless wire format.
    let stdout = run_ok(h2_bin().args([
        "fleet", "--cluster", "A=64", "--trace", trace_path, "--out", out_b,
    ]));
    assert_eq!(parse_line(&stdout, "fleet_p99_wait_seconds "), p99_a);
    let a = std::fs::read_to_string(out_a).unwrap();
    let b = std::fs::read_to_string(out_b).unwrap();
    assert_eq!(a, b, "timeline files must be byte-identical");

    // The emitted trace parses back in-process too.
    let trace = JobTrace::load(trace_path).unwrap();
    assert_eq!(trace.seed, 7);
    assert_eq!(trace.jobs.len(), 4);

    // A bogus policy token fails loudly.
    let out = h2_bin()
        .args(["fleet", "--cluster", "A=64", "--trace", trace_path, "--policy", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bad --policy must be rejected");
}

#[test]
fn fleet_cli_faulty_timelines_are_byte_identical_across_repeats() {
    let dir = tmp_dir("faults");
    let out_a = dir.join("a.json");
    let out_a = out_a.to_str().unwrap();
    let out_b = dir.join("b.json");
    let out_b = out_b.to_str().unwrap();

    // `--faults pinned` derives the fault plan from a silent healthy
    // prerun of the same trace — the whole pipeline must be a pure
    // function of (cluster, trace, flags).
    let args = [
        "fleet", "--cluster", "A=64,B=64", "--trace", "pinned",
        "--faults", "pinned", "--ckpt-every", "10",
    ];
    let stdout = run_ok(h2_bin().args(args).args(["--out", out_a]));
    assert_ne!(parse_line(&stdout, "fleet_faults "), "0");
    let goodput = parse_line(&stdout, "fleet_goodput ").to_string();
    let recovery = parse_line(&stdout, "fleet_recovery_seconds ").to_string();

    let stdout = run_ok(h2_bin().args(args).args(["--out", out_b]));
    assert_eq!(parse_line(&stdout, "fleet_goodput "), goodput);
    assert_eq!(parse_line(&stdout, "fleet_recovery_seconds "), recovery);
    let a = std::fs::read_to_string(out_a).unwrap();
    let b = std::fs::read_to_string(out_b).unwrap();
    assert_eq!(a, b, "faulty timeline files must be byte-identical");
    assert!(a.contains("\"fault\""), "timeline must carry fault events");

    // The restart baseline is a different, valid run of the same faults.
    let stdout = run_ok(h2_bin().args(args).args(["--fault-response", "restart"]));
    assert_ne!(parse_line(&stdout, "fleet_goodput "), goodput, "responses must differ");

    // A bogus response token fails loudly.
    let out = h2_bin().args(args).args(["--fault-response", "bogus"]).output().unwrap();
    assert!(!out.status.success(), "bad --fault-response must be rejected");
}
