//! Fleet-scheduler integration tests: the determinism contract (same
//! trace + policy ⇒ bit-identical [`FleetTimeline`] JSON, for any worker
//! count), the policy contrast the pinned trace exists to show
//! (priority-with-backfill beats FIFO on p99 job wait), and the CLI
//! round-trip of a trace file through `h2 fleet`.

use std::path::PathBuf;
use std::process::Command;

use h2::fleet::{
    fleet_search_config, run, FleetEventKind, FleetOptions, FleetTimeline, FreePool, JobModel,
    JobSpec, JobTrace, PlaceOutcome, Policy, Scheduler,
};
use h2::hetero::{spec, ChipKind, Cluster};

/// The two-vendor lab cluster the in-process tests run on: big enough
/// that the pinned trace's whole-cluster jobs are searchable and its
/// 64-chip jobs leave contention, small enough to keep the inner
/// HeteroAuto solves fast.
fn lab() -> Cluster {
    Cluster::new("lab", vec![(ChipKind::A, 64), (ChipKind::B, 64)])
}

fn run_policy(cluster: &Cluster, trace: &JobTrace, policy: Policy, workers: usize) -> FleetTimeline {
    let opts = FleetOptions { policy, workers, ..FleetOptions::default() };
    run(cluster, trace, &opts).expect("fleet run failed")
}

#[test]
fn pinned_trace_contrast_priority_beats_fifo_on_p99_wait() {
    let cluster = lab();
    let trace = JobTrace::pinned(cluster.total_chips());

    let fifo = run_policy(&cluster, &trace, Policy::Fifo, 1);
    let pri = run_policy(&cluster, &trace, Policy::PriorityBackfill, 1);

    // Both policies finish the whole queue on this cluster.
    for tl in [&fifo, &pri] {
        assert_eq!(tl.metrics.jobs, trace.jobs.len());
        assert_eq!(tl.metrics.completed, trace.jobs.len(), "{:?}", tl.metrics);
        assert_eq!(tl.metrics.rejected, 0);
        assert!(tl.metrics.utilization > 0.0 && tl.metrics.utilization <= 1.0 + 1e-9);
    }

    // The contrast the trace is built for: under FIFO the second
    // whole-cluster job blocks the burst of small high-priority jobs, so
    // its long runtime lands in their waits; under priority-with-backfill
    // they overtake it. p99 wait must fall — structurally, not by luck.
    assert!(
        pri.metrics.p99_wait_seconds < fifo.metrics.p99_wait_seconds,
        "priority p99 {} should beat fifo p99 {}",
        pri.metrics.p99_wait_seconds,
        fifo.metrics.p99_wait_seconds
    );
    assert_ne!(fifo.metrics, pri.metrics, "policies must be distinguishable");

    // Event-stream sanity on both timelines.
    for tl in [&fifo, &pri] {
        let mut prev = 0.0f64;
        for e in &tl.events {
            assert!(e.t_seconds >= prev, "events out of order: {:?}", tl.events);
            prev = e.t_seconds;
            if let FleetEventKind::Resize { freed_chips, migrate_seconds, .. } = e.kind {
                assert!(freed_chips > 0);
                assert!(migrate_seconds >= 0.0);
            }
        }
        for j in &tl.jobs {
            let w = j.wait_seconds.expect("all jobs completed");
            assert!(w >= 0.0, "negative wait for job {}", j.id);
            assert!(j.finish_seconds.expect("finished") >= j.arrival_seconds + w);
        }
    }
}

#[test]
fn timeline_is_bit_identical_across_repeats_and_worker_counts() {
    let cluster = lab();
    let trace = JobTrace::pinned(cluster.total_chips());

    // Repeats (fresh Scheduler, fresh ProfileCache each time)...
    let a = run_policy(&cluster, &trace, Policy::PriorityBackfill, 1);
    let b = run_policy(&cluster, &trace, Policy::PriorityBackfill, 1);
    assert_eq!(a.to_json_string(), b.to_json_string(), "repeat determinism");

    // ...and worker counts are purely wall-clock knobs.
    let c = run_policy(&cluster, &trace, Policy::PriorityBackfill, 4);
    assert_eq!(a.to_json_string(), c.to_json_string(), "worker-count invariance");
}

#[test]
fn generated_trace_runs_deterministically_end_to_end() {
    // One vendor, whole-cluster jobs: the generator path (Poisson
    // arrivals, bursts) through the full loop, twice.
    let cluster = Cluster::new("solo", vec![(ChipKind::A, 64)]);
    let trace = JobTrace::generate(7, 5, cluster.total_chips());
    assert_eq!(trace.jobs.len(), 5);

    let a = run_policy(&cluster, &trace, Policy::Fifo, 0);
    let b = run_policy(&cluster, &trace, Policy::Fifo, 0);
    assert_eq!(a.to_json_string(), b.to_json_string());
    assert_eq!(a.metrics.completed + a.metrics.rejected, 5);
    // Whole-node allocations only, ever.
    let node = spec(ChipKind::A).chips_per_node;
    for j in &a.jobs {
        assert_eq!(j.chips % node, 0, "ragged allocation for job {}", j.id);
    }
}

#[test]
fn failed_preemption_shrink_leaves_the_free_pool_untouched() {
    // A victim whose only chip group is a single node is not
    // swap-compatible with any shrink: `try_shrink` must keep at least
    // one node per group, so it can never free chips here — and a
    // placement round built on that failed shrink must leave the
    // `FreePool` exactly as it was.
    let cluster = Cluster::new("one-node", vec![(ChipKind::A, 16)]);
    let sched = Scheduler::new(Policy::PriorityBackfill, fleet_search_config());
    let mut pool = FreePool::new(&cluster);
    assert_eq!(pool.total(), cluster.total_chips());

    let victim_job = JobSpec {
        id: 0,
        model: JobModel::H20B,
        gbs_tokens: 128 * 4096,
        priority: 0,
        arrival_step: 0,
        min_chips: 16,
        max_chips: 16,
        steps: 10,
    };
    let PlaceOutcome::Placed(victim) = sched.try_place(&victim_job, &mut pool) else {
        panic!("victim placement failed on an idle one-node cluster");
    };
    // Chip accounting after the take: pool + held allocation = cluster.
    assert_eq!(victim.chips, 16);
    assert_eq!(pool.total() + victim.chips, cluster.total_chips());
    let snapshot = pool.clone();

    // A higher-priority arrival needs a whole node; the only victim
    // cannot shed one and survive, so the shrink must fail...
    let need = 16;
    assert!(
        sched.try_shrink(&victim.plan, 1.0, need).is_none(),
        "a one-node victim must never shrink"
    );
    // ...and the pool is bit-for-bit what it was before the attempt.
    assert_eq!(pool, snapshot);
    assert_eq!(pool.total() + victim.chips, cluster.total_chips());

    // The round then resolves to NoCapacity for the contender — which
    // also must not touch the pool.
    let contender = JobSpec { id: 1, priority: 3, arrival_step: 1, ..victim_job.clone() };
    assert!(matches!(sched.try_place(&contender, &mut pool), PlaceOutcome::NoCapacity));
    assert_eq!(pool, snapshot);

    // Releasing the victim restores the idle pool exactly.
    pool.release(&victim.alloc);
    assert_eq!(pool, FreePool::new(&cluster));
}

#[test]
fn oversized_jobs_are_rejected_up_front() {
    let cluster = Cluster::new("solo", vec![(ChipKind::A, 64)]);
    let mut trace = JobTrace::pinned(64);
    trace.jobs[0].min_chips = 128; // cluster only has 64
    trace.jobs[0].max_chips = 128;
    let err = run(&cluster, &trace, &FleetOptions::default()).unwrap_err();
    assert!(err.to_string().contains("128"), "unexpected error: {err}");
}

// ---------------------------------------------------------------------
// CLI: `h2 fleet` round-trips a trace file.

fn h2_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_h2"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("h2_fleet_tests").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawning h2");
    assert!(
        out.status.success(),
        "h2 {:?} failed:\nstdout: {}\nstderr: {}",
        cmd.get_args().collect::<Vec<_>>(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

/// A machine-readable `<prefix> <value>` line from stdout.
fn parse_line<'a>(stdout: &'a str, prefix: &str) -> &'a str {
    stdout
        .lines()
        .find_map(|l| l.strip_prefix(prefix))
        .unwrap_or_else(|| panic!("no `{prefix}` line in:\n{stdout}"))
        .trim()
}

#[test]
fn fleet_cli_round_trips_a_trace_file() {
    let dir = tmp_dir("roundtrip");
    let trace_path = dir.join("trace.json");
    let trace_path = trace_path.to_str().unwrap();
    let out_a = dir.join("a.json");
    let out_a = out_a.to_str().unwrap();
    let out_b = dir.join("b.json");
    let out_b = out_b.to_str().unwrap();

    // Generate from a seed, emitting both the trace and the timeline.
    let stdout = run_ok(h2_bin().args([
        "fleet", "--cluster", "A=64", "--trace", "7", "--jobs", "4",
        "--emit-trace", trace_path, "--out", out_a,
    ]));
    assert_eq!(parse_line(&stdout, "fleet_policy "), "fifo");
    assert_eq!(parse_line(&stdout, "fleet_jobs "), "4");
    let p99_a = parse_line(&stdout, "fleet_p99_wait_seconds ").to_string();

    // Replaying the emitted trace file reproduces the timeline
    // bit-for-bit — trace JSON is a lossless wire format.
    let stdout = run_ok(h2_bin().args([
        "fleet", "--cluster", "A=64", "--trace", trace_path, "--out", out_b,
    ]));
    assert_eq!(parse_line(&stdout, "fleet_p99_wait_seconds "), p99_a);
    let a = std::fs::read_to_string(out_a).unwrap();
    let b = std::fs::read_to_string(out_b).unwrap();
    assert_eq!(a, b, "timeline files must be byte-identical");

    // The emitted trace parses back in-process too.
    let trace = JobTrace::load(trace_path).unwrap();
    assert_eq!(trace.seed, 7);
    assert_eq!(trace.jobs.len(), 4);

    // A bogus policy token fails loudly.
    let out = h2_bin()
        .args(["fleet", "--cluster", "A=64", "--trace", trace_path, "--policy", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bad --policy must be rejected");
}
