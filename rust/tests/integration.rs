//! Cross-module integration tests: HeteroAuto ↔ cost model ↔ simulator
//! consistency, DiComm model invariants, manifest failure injection, and
//! end-to-end properties over the whole search space.

use h2::auto::{search, SearchConfig};
use h2::comm::{cross_node_time, p2p_latency, CommAlgo, CommMode};
use h2::costmodel::{evaluate, GroupPlan, Schedule, Strategy, H2_100B, MEMORY_SAFETY};
use h2::hetero::{experiment, spec, ChipKind, Cluster, ALL_EXPERIMENTS};
use h2::sim::{simulate_iteration, SimOptions};
use h2::topology::NicAssignment;
use h2::util::prop;
use h2::util::rng::Rng;

#[test]
fn every_experiment_search_is_consistent() {
    for exp_name in ALL_EXPERIMENTS {
        let exp = experiment(exp_name).unwrap();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())
            .unwrap_or_else(|e| panic!("{exp_name}: {e}"));
        // Invariant 1: all layers placed.
        assert_eq!(r.strategy.total_layers(), H2_100B.n_layers, "{exp_name}");
        // Invariant 2: exact chip accounting per group.
        for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
            assert_eq!(g.n_chips, p.s_pp * p.s_tp * r.strategy.s_dp,
                       "{exp_name}/{}", g.spec.kind);
            // Invariant 3: TP is a power of two within TP_MAX.
            assert!(p.s_tp.is_power_of_two());
            assert!(p.s_tp <= g.spec.tp_max());
            // Invariant 4: layers uniform across a type's stages.
            assert_eq!(p.layers % p.s_pp, 0);
        }
        // Invariant 5: memory feasible under the safety margin.
        assert!(r.eval.feasible, "{exp_name}");
        for (g, &mem) in r.groups.iter().zip(&r.eval.peak_memory) {
            assert!(mem <= g.spec.memory_bytes() * MEMORY_SAFETY + 1.0, "{exp_name}");
        }
        // Invariant 6: the simulator agrees with the cost model (they share
        // profiles but schedule independently). 1F1B matches within 25%;
        // the other schedules carry discrete-event effects the closed
        // form's single coefficient cannot see (the zero-bubble warm-up
        // residual, interleaving's wrap-around hops), so their band is
        // wider.
        let grefs: Vec<&h2::hetero::ChipGroup> = r.groups.iter().collect();
        let sim = simulate_iteration(&H2_100B, &grefs, &r.strategy, H2_100B.seq_len,
                                     &SimOptions::default());
        let rel = (sim.iteration_seconds - r.eval.iteration_seconds).abs()
            / r.eval.iteration_seconds;
        let tol = match r.strategy.schedule {
            Schedule::OneF1B => 0.25,
            _ => 0.5,
        };
        assert!(rel < tol, "{exp_name} ({}): sim {} vs model {}",
                r.strategy.schedule, sim.iteration_seconds, r.eval.iteration_seconds);
    }
}

#[test]
fn per_schedule_and_algo_parity_on_searched_plans() {
    // For each (comm algo x schedule) pair: pin the search, package the
    // winner as a plan, and check the discrete-event simulator against
    // the closed-form view of the *same* strategy. 1F1B is the calibrated
    // pair; the other schedules stay within a wider band (their
    // issue-order effects are folded into one coefficient in the closed
    // form). Both evaluators price the collective algorithm through the
    // same profile, so the parity band is algorithm-independent.
    let exp = experiment("exp-a-1").unwrap();
    for comm_algo in [CommAlgo::Ring, CommAlgo::Hierarchical, CommAlgo::Auto] {
        for (schedule, tol) in [
            (Schedule::OneF1B, 0.25),
            (Schedule::Interleaved { virtual_stages: 2 }, 0.5),
            (Schedule::ZeroBubbleV, 0.5),
        ] {
            let cfg = SearchConfig {
                comm_algos: vec![comm_algo],
                two_stage: false,
                ..SearchConfig::pinned(schedule)
            };
            let r = match search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
                Ok(r) => r,
                // Interleaving may be infeasible on a heterogeneous cluster
                // when no layer split chunks evenly — nothing to compare.
                Err(_) => continue,
            };
            assert_eq!(r.strategy.schedule, schedule);
            assert_eq!(r.strategy.comm_algo, comm_algo);
            let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
            let sim = plan.simulate();
            let cm = plan.evaluate();
            let rel = (sim.iteration_seconds - cm.iteration_seconds).abs()
                / cm.iteration_seconds;
            assert!(rel < tol, "{comm_algo}/{schedule}: sim {} vs model {} (rel {rel})",
                    sim.iteration_seconds, cm.iteration_seconds);
        }
    }
}

#[test]
fn mega_cluster_two_stage_search_roundtrips_through_plan_json() {
    // The paper-scale scenario end to end: 1,280 chips across all four
    // vendors, full two-stage search (every group splits into 128-chip
    // subgroups), winner packaged as a plan that survives the JSON
    // round-trip bit for bit.
    use h2::plan::ExecutionPlan;
    let exp = experiment("exp-mega").unwrap();
    assert!(exp.cluster.total_chips() > 1000);
    assert_eq!(exp.cluster.n_types(), 4);
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default()).unwrap();
    assert!(r.eval.feasible);
    assert_eq!(r.strategy.total_layers(), H2_100B.n_layers);
    assert!(r.candidates_explored > 0);
    // Exact chip accounting across every (sub)group.
    for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
        assert_eq!(g.n_chips, p.s_pp * p.s_tp * r.strategy.s_dp, "{}", g.spec.kind);
    }
    let strategy = r.strategy.clone();
    let eval_iter = r.eval.iteration_seconds;
    let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
    assert!(plan.validate().is_ok());
    let loaded = ExecutionPlan::from_json_str(&plan.to_json_string()).unwrap();
    assert_eq!(loaded, plan);
    assert_eq!(loaded.strategy, strategy);
    assert_eq!(loaded.evaluate().iteration_seconds, eval_iter);
}

#[test]
fn search_monotone_in_batch_size() {
    // Larger global batch must never raise the searched cost-per-token.
    let exp = experiment("exp-a-1").unwrap();
    let cfg = SearchConfig::default();
    let small = search(&H2_100B, &exp.cluster, 2 * 1024 * 1024, &cfg).unwrap();
    let large = search(&H2_100B, &exp.cluster, 6 * 1024 * 1024, &cfg).unwrap();
    let per_tok_small = small.eval.iteration_seconds / (2.0 * 1024.0 * 1024.0);
    let per_tok_large = large.eval.iteration_seconds / (6.0 * 1024.0 * 1024.0);
    assert!(per_tok_large <= per_tok_small * 1.001);
}

#[test]
fn random_feasible_strategies_never_beat_search() {
    // Property: HeteroAuto's pick is at least as good as random feasible
    // strategies drawn from the same space (both sides pinned to 1F1B so
    // the comparison is schedule-for-schedule).
    let exp = experiment("exp-a-1").unwrap();
    let best = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                      &SearchConfig {
                          two_stage: false,
                          ..SearchConfig::pinned(Schedule::OneF1B)
                      }).unwrap();
    let groups: Vec<h2::hetero::ChipGroup> =
        exp.cluster.groups_by_memory_desc().into_iter().cloned().collect();
    let sequences = exp.gbs_tokens / H2_100B.seq_len;

    prop::check(60, |rng: &mut Rng| {
        let dps = [1usize, 2, 4, 8, 16, 32];
        let s_dp = *rng.choose(&dps);
        if sequences % s_dp != 0 {
            return Ok(());
        }
        let mut plans = Vec::new();
        for g in &groups {
            let tps = [1usize, 2, 4];
            let s_tp = *rng.choose(&tps);
            if g.n_chips % (s_tp * s_dp) != 0 {
                return Ok(());
            }
            let s_pp = g.n_chips / (s_tp * s_dp);
            plans.push(GroupPlan { s_pp, s_tp, layers: 0, recompute: rng.f64() < 0.5 });
        }
        // Random layer split (uniform within type).
        let mut remaining = H2_100B.n_layers;
        let n = plans.len();
        for (i, p) in plans.iter_mut().enumerate() {
            let lps = if i == n - 1 {
                remaining / p.s_pp
            } else {
                rng.usize(1, (remaining / p.s_pp).max(2))
            };
            let take = (lps * p.s_pp).min(remaining);
            p.layers = take;
            remaining -= take;
        }
        if remaining != 0 || plans.iter().any(|p| p.layers == 0 || p.layers % p.s_pp != 0) {
            return Ok(());
        }
        let strategy = Strategy {
            s_ep: 1,
            s_dp,
            micro_batches: sequences / s_dp,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Auto,
            plans,
        };
        let grefs: Vec<&h2::hetero::ChipGroup> = groups.iter().collect();
        let eval = evaluate(&H2_100B, &grefs, &strategy, H2_100B.seq_len);
        if !eval.feasible {
            return Ok(());
        }
        prop::assert_prop(
            eval.iteration_seconds >= best.eval.iteration_seconds * 0.999,
            format!("random strategy {strategy:?} beat the search: {} < {}",
                    eval.iteration_seconds, best.eval.iteration_seconds),
        )
    });
}

#[test]
fn hierarchical_beats_flat_ring_on_a_two_node_mixed_vendor_fixture() {
    // Two custom vendors, one 8-chip node each per group, with an
    // NVLink-class intra fabric (200 GB/s) and a ~2 GB/s per-flow NIC
    // path (intra >= 4x inter, comfortably). At TP 2 / DP 8 each stage's
    // DP group spans both of its vendor's nodes, so the collective choice
    // is visible end to end: the two-level allreduce must beat the flat
    // ring in BOTH the closed-form cost model and the discrete-event
    // simulator, on the same strategy.
    use h2::costmodel::ModelShape;
    use h2::hetero::{register_custom, ChipGroup, CustomChipDef, IntraNodeLink};

    let mut chips = Vec::new();
    for name in ["IntTest-HX", "IntTest-HY"] {
        let mut def = CustomChipDef::new(name);
        def.fp16_tflops = if name.ends_with('X') { 200.0 } else { 320.0 };
        def.memory_gib = 64.0;
        def.chips_per_node = 8;
        def.intra_node = IntraNodeLink::Uniform { gbps: 200.0 };
        def.nics_per_node = 8;
        def.nic_gbps = 25.0;
        def.pcie_to_nic_gbps = 2.5; // x RDMA efficiency -> 2 GB/s flows
        chips.push(register_custom(&def).unwrap());
    }
    let groups: Vec<ChipGroup> =
        chips.iter().map(|&k| ChipGroup::try_new(k, 16).unwrap()).collect();
    let grefs: Vec<&ChipGroup> = groups.iter().collect();
    let model = ModelShape {
        n_layers: 8,
        hidden: 4096,
        n_heads: 32,
        n_kv_heads: 8,
        intermediate: 11008,
        vocab: 32000,
        seq_len: 4096,
        n_experts: 0,
        top_k: 0,
        expert_intermediate: 0,
    };
    let mk = |comm_algo| Strategy {
        s_ep: 1,
        s_dp: 8,
        micro_batches: 4,
        schedule: Schedule::OneF1B,
        comm_algo,
        plans: vec![
            GroupPlan { s_pp: 1, s_tp: 2, layers: 4, recompute: false },
            GroupPlan { s_pp: 1, s_tp: 2, layers: 4, recompute: false },
        ],
    };
    let ring = mk(CommAlgo::Ring);
    let hier = mk(CommAlgo::Hierarchical);

    let cm_ring = evaluate(&model, &grefs, &ring, model.seq_len);
    let cm_hier = evaluate(&model, &grefs, &hier, model.seq_len);
    assert!(cm_hier.iteration_seconds < cm_ring.iteration_seconds,
            "cost model: hier {} !< ring {}",
            cm_hier.iteration_seconds, cm_ring.iteration_seconds);

    let sim_ring = simulate_iteration(&model, &grefs, &ring, model.seq_len,
                                      &SimOptions::default());
    let sim_hier = simulate_iteration(&model, &grefs, &hier, model.seq_len,
                                      &SimOptions::default());
    assert!(sim_hier.iteration_seconds < sim_ring.iteration_seconds,
            "simulator: hier {} !< ring {}",
            sim_hier.iteration_seconds, sim_ring.iteration_seconds);

    // The auto selector picks the winning side on this fabric.
    let auto = mk(CommAlgo::Auto);
    let cm_auto = evaluate(&model, &grefs, &auto, model.seq_len);
    assert!(cm_auto.iteration_seconds <= cm_hier.iteration_seconds,
            "auto {} vs hier {}", cm_auto.iteration_seconds, cm_hier.iteration_seconds);
}

#[test]
fn comm_model_invariants() {
    prop::check(200, |rng: &mut Rng| {
        let bytes = 1usize << rng.usize(6, 30);
        let tcp = p2p_latency(CommMode::TcpCpu, bytes);
        let mid = p2p_latency(CommMode::RdmaCpu, bytes);
        let ddr = p2p_latency(CommMode::DeviceDirect, bytes);
        prop::assert_prop(ddr > 0.0 && ddr.is_finite(), "positive finite")?;
        prop::assert_prop(ddr <= mid && mid <= tcp, "strategy ordering")?;
        // Doubling the size never more than doubles-plus-overhead the time.
        let ddr2 = p2p_latency(CommMode::DeviceDirect, bytes * 2);
        prop::assert_prop(ddr2 >= ddr && ddr2 <= 2.0 * ddr + 1e-5, "subadditive growth")
    });
}

#[test]
fn cross_node_time_symmetric_in_affinity_ordering() {
    for src in ChipKind::ALL {
        for dst in ChipKind::ALL {
            let s = spec(src);
            let d = spec(dst);
            for mode in [CommMode::TcpCpu, CommMode::RdmaCpu, CommMode::DeviceDirect] {
                let aff = cross_node_time(mode, 1 << 20, &s, &d, NicAssignment::Affinity);
                let non = cross_node_time(mode, 1 << 20, &s, &d, NicAssignment::NonAffinity);
                assert!(aff <= non, "{src}->{dst} {mode:?}");
            }
        }
    }
}

#[test]
fn sub_sequence_batch_reports_error() {
    let cluster = Cluster::new("c16", vec![(ChipKind::C, 16)]);
    let r = search(&H2_100B, &cluster, 1000, &SearchConfig::default());
    assert!(r.is_err(), "GBS below one sequence must error");
}

#[test]
fn tiny_cluster_survives_only_via_offload() {
    // One C node (16 x 32 GiB) holds the 100B model only by spilling
    // optimizer state to host — the search must find that plan and the
    // memory model must mark it offloaded.
    let cluster = Cluster::new("c16", vec![(ChipKind::C, 16)]);
    let r = search(&H2_100B, &cluster, 2 * 1024 * 1024, &SearchConfig::default()).unwrap();
    assert!(r.eval.feasible);
    let plan = &r.strategy.plans[0];
    let groups = cluster.groups_by_memory_desc();
    let mem = h2::costmodel::stage_memory_bytes(
        &groups[0].spec, &H2_100B, plan, &r.strategy, 0,
        r.strategy.total_stages(), H2_100B.seq_len, true,
        plan.s_pp == r.strategy.total_stages(),
    );
    assert!(mem.offloaded, "a single C node must need offload for 100B");
}

#[test]
fn zero_bubble_schedule_improves_every_experiment() {
    for exp_name in ["exp-a-1", "exp-c-1"] {
        let exp = experiment(exp_name).unwrap();
        let f1b1 = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig {
                              two_stage: false,
                              ..SearchConfig::pinned(Schedule::OneF1B)
                          })
            .unwrap();
        let zbv = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig {
                             two_stage: false,
                             ..SearchConfig::pinned(Schedule::ZeroBubbleV)
                         })
            .unwrap();
        assert!(zbv.eval.iteration_seconds < f1b1.eval.iteration_seconds, "{exp_name}");
    }
}

mod manifest_failures {
    use h2::runtime::Manifest;
    use std::io::Write;

    fn write_tmp(name: &str, content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("h2_manifest_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn missing_file_errors() {
        assert!(Manifest::load("/nonexistent/manifest.json").is_err());
    }

    #[test]
    fn malformed_json_errors() {
        let p = write_tmp("bad.json", "{ not json ]");
        assert!(Manifest::load(&p).is_err());
    }

    #[test]
    fn missing_keys_error_with_context() {
        let p = write_tmp("empty.json", r#"{"models": {"m": {"config": {}, "artifacts": {}}}}"#);
        let err = Manifest::load(&p).unwrap_err().to_string();
        assert!(err.contains("n_layers") || err.contains("missing key"), "{err}");
    }

    #[test]
    fn valid_minimal_manifest_loads() {
        let p = write_tmp("ok.json", r#"{"models": {"m": {"config": {
            "n_layers": 2, "hidden": 8, "n_heads": 2, "n_kv_heads": 1,
            "intermediate": 16, "vocab": 32, "seq_len": 16, "param_count": 1234},
            "artifacts": {"x": {"file": "m/x.hlo.txt",
              "inputs": [{"shape": [2, 2], "dtype": "f32"}],
              "outputs": [{"shape": [], "dtype": "f32"}]}}}}}"#);
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.model("m").unwrap().n_layers, 2);
        let a = m.artifact("m", "x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 2]);
        assert!(a.params.is_empty());
    }
}

mod collective_failure_injection {
    use h2::comm::collectives::ring_allreduce;
    use h2::util::prop;
    use h2::util::rng::Rng;

    #[test]
    #[should_panic(expected = "rank buffer lengths differ")]
    fn mismatched_lengths_panic() {
        let mut bufs = vec![vec![0.0f32; 4], vec![0.0f32; 5]];
        ring_allreduce(&mut bufs, &|_| 0.0);
    }

    #[test]
    fn allreduce_handles_non_divisible_lengths() {
        // Lengths that don't divide evenly across ranks still reduce right.
        prop::check(50, |rng: &mut Rng| {
            let n = rng.usize(2, 9);
            let len = rng.usize(1, 3 * n + 1); // often < n, exercising empty chunks
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|r| vec![(r + 1) as f32; len]).collect();
            let expect = (n * (n + 1) / 2) as f32;
            ring_allreduce(&mut bufs, &|_| 0.0);
            for b in &bufs {
                for &x in b {
                    prop::assert_prop((x - expect).abs() < 1e-4,
                                      format!("{x} != {expect} (n={n}, len={len})"))?;
                }
            }
            Ok(())
        });
    }
}
