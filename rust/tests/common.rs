//! Shared fixture of the three-evaluator parity suites (`parity.rs` and
//! the CLI tests in `cli_plan.rs`): one 2-stage mixed-vendor plan, so the
//! in-process and CLI assertions are guaranteed to run the same strategy.
//! (`coordinator/exec.rs`'s unit tests mirror this plan — integration
//! helpers are unreachable from the lib crate — keep the two in sync.)
//!
//! Included via `mod common;` from each integration-test target
//! (`autotests = false` keeps cargo from compiling it standalone).

use h2::comm::CommAlgo;
use h2::costmodel::{GroupPlan, ModelShape, Schedule, Strategy};
use h2::hetero::{ChipKind, Cluster};
use h2::plan::ExecutionPlan;
use h2::plan::PlanBuilder;

/// A small transformer whose 8 layers split evenly over 2 stages (and
/// chunk under `interleaved:2`).
pub fn tiny_model() -> ModelShape {
    ModelShape {
        n_layers: 8,
        hidden: 2048,
        n_heads: 16,
        n_kv_heads: 16,
        intermediate: 8192,
        vocab: 32000,
        seq_len: 4096,
        n_experts: 0,
        top_k: 0,
        expert_intermediate: 0,
    }
}

/// The 2-stage mixed-vendor fixture: Chip A (96 GiB/chip, 16 chips/node)
/// feeding Chip B (64 GiB/chip, 8 chips/node), TP 4 and DP 4 on both. On
/// Chip B only 2 of the 4 DP replicas share a node, so the DP gradient
/// sync crosses nodes and the collective algorithm matters.
pub fn two_stage_mixed_vendor_plan(schedule: Schedule, comm_algo: CommAlgo) -> ExecutionPlan {
    let cluster = Cluster::new("parity-2stage", vec![(ChipKind::A, 16), (ChipKind::B, 16)]);
    PlanBuilder::new("parity")
        .model(tiny_model())
        .cluster(cluster)
        .strategy(Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 8,
            schedule,
            comm_algo,
            plans: vec![
                GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: false },
                GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: true },
            ],
        })
        .gbs_tokens(4 * 8 * 4096)
        .build()
        .unwrap()
}
