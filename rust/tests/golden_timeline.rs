//! Golden-timeline snapshot suite: the arena engine's [`EventTimeline`]
//! for the parity fixture, pinned as checked-in JSON across every
//! (schedule × comm-algo) pair under `rust/tests/golden/`.
//!
//! Self-seeding: a missing snapshot is generated, written, and reported —
//! the CI step runs this suite twice, so run 2 pins the files run 1 wrote
//! on a fresh checkout that predates them. After an *intentional* engine
//! change, regenerate with `H2_BLESS=1 cargo test --test golden_timeline`
//! and commit the diff; an unintentional drift fails with the first
//! mismatching event.
//!
//! The DP-collective algorithm only affects update-time pricing, never the
//! pipeline event clock, so the per-algo snapshots are intentionally
//! event-identical per schedule — the pair-wise files exist to pin exactly
//! that invariant alongside the timestamps themselves.

mod common;

use std::env;
use std::fs;
use std::path::PathBuf;

use h2::comm::CommAlgo;
use h2::costmodel::Schedule;
use h2::sim::reference::simulate_iteration_reference_timeline;
use h2::sim::{EventTimeline, SimEngine};
use h2::util::json::Value;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn golden_path(schedule: Schedule, algo: CommAlgo) -> PathBuf {
    golden_dir().join(format!(
        "timeline_{}_{}.json",
        schedule.token().replace(':', ""),
        algo.token()
    ))
}

#[test]
fn golden_timelines_pin_every_schedule_and_comm_algo() {
    let bless = env::var("H2_BLESS").map(|v| v == "1").unwrap_or(false);
    for schedule in Schedule::SEARCH_SPACE {
        for algo in CommAlgo::ALL {
            let plan = common::two_stage_mixed_vendor_plan(schedule, algo);
            let (_, timeline) = SimEngine::for_plan(&plan).run_timeline();
            assert!(!timeline.events.is_empty(), "{schedule} x {}", algo.token());
            let path = golden_path(schedule, algo);
            if bless || !path.exists() {
                fs::create_dir_all(golden_dir()).unwrap();
                fs::write(&path, timeline.to_json().to_string_pretty()).unwrap();
                eprintln!("seeded golden timeline {} — commit it to pin", path.display());
                continue;
            }
            let text = fs::read_to_string(&path).unwrap();
            let golden = EventTimeline::from_json(&Value::parse(&text).unwrap()).unwrap();
            if let Some(diff) = golden.diff(&timeline) {
                panic!(
                    "{} drifted from its golden snapshot: {diff}\n(set H2_BLESS=1 to \
                     regenerate after an intentional engine change)",
                    path.file_name().unwrap().to_string_lossy()
                );
            }
        }
    }
}

#[test]
fn reference_shim_emits_the_same_timeline_as_the_engine() {
    // The old-path shim (reference executors + timeline recording) and the
    // arena engine must agree on every event, bit-for-bit — the in-process
    // half of the golden contract, independent of any checked-in file.
    for schedule in Schedule::SEARCH_SPACE {
        for algo in [CommAlgo::Ring, CommAlgo::Hierarchical] {
            let plan = common::two_stage_mixed_vendor_plan(schedule, algo);
            let (eng_sim, eng_t) = SimEngine::for_plan(&plan).run_timeline();
            let groups = plan.group_refs();
            let (ref_sim, ref_t) = simulate_iteration_reference_timeline(
                &plan.model,
                &groups,
                &plan.strategy,
                plan.micro_tokens,
                &plan.sim_options(),
            );
            assert_eq!(
                ref_t.diff(&eng_t),
                None,
                "{schedule} x {}: engine and reference timelines diverged",
                algo.token()
            );
            assert_eq!(
                eng_sim.iteration_seconds,
                ref_sim.iteration_seconds,
                "{schedule} x {}",
                algo.token()
            );
        }
    }
}

#[test]
fn timeline_json_roundtrip_is_bit_exact() {
    let plan = common::two_stage_mixed_vendor_plan(Schedule::ZeroBubbleV, CommAlgo::Ring);
    let (_, timeline) = SimEngine::for_plan(&plan).run_timeline();
    let text = timeline.to_json().to_string_pretty();
    let back = EventTimeline::from_json(&Value::parse(&text).unwrap()).unwrap();
    assert_eq!(timeline, back);
    assert_eq!(timeline.diff(&back), None);
}
