//! CLI-level plan workflow tests: `h2 search --emit-plan` →
//! `h2 simulate --plan` must reproduce the in-process
//! `SearchResult → simulate` path bit-for-bit, and `--config` must work
//! uniformly across subcommands — including clusters made of chips that
//! exist only in the config JSON.

mod common;

use std::path::PathBuf;
use std::process::Command;

use h2::auto::{search, SearchConfig};
use h2::costmodel::{Schedule, H2_100B};
use h2::hetero::{ChipKind, Cluster};
use h2::plan::ExecutionPlan;
use h2::sim::simulate_plan;

fn h2_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_h2"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("h2_cli_plan_tests").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawning h2");
    assert!(
        out.status.success(),
        "h2 {:?} failed:\nstdout: {}\nstderr: {}",
        cmd.get_args().collect::<Vec<_>>(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

/// The machine-readable last line `simulate` prints.
fn parse_iteration_seconds(stdout: &str) -> String {
    stdout
        .lines()
        .find_map(|l| l.strip_prefix("iteration_seconds "))
        .unwrap_or_else(|| panic!("no iteration_seconds line in:\n{stdout}"))
        .to_string()
}

#[test]
fn search_emit_plan_then_simulate_matches_in_process_bit_for_bit() {
    let dir = tmp_dir("parity");
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();

    run_ok(h2_bin().args([
        "search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1", "--emit-plan", plan_path,
    ]));
    let stdout = run_ok(h2_bin().args(["simulate", "--plan", plan_path]));
    let cli_iter = parse_iteration_seconds(&stdout);

    // The same pipeline in-process, no file in between.
    let cluster = Cluster::new("custom", vec![(ChipKind::A, 16), (ChipKind::B, 16)]);
    let gbs = 1024 * 1024;
    let cfg = SearchConfig::default();
    let r = search(&H2_100B, &cluster, gbs, &cfg).unwrap();
    let plan = r.into_plan(&H2_100B, &cluster, gbs);
    let in_process = format!("{:.17e}", simulate_plan(&plan).iteration_seconds);

    assert_eq!(cli_iter, in_process, "plan file round-trip changed the simulation");

    // The persisted plan deserializes to exactly the in-process plan.
    let loaded = ExecutionPlan::load(plan_path).unwrap();
    assert_eq!(loaded, plan);
}

const CUSTOM_CHIP_CONFIG: &str = r#"{
    "chips": [{"name": "CliTest-Q1", "fp16_tflops": 250, "memory_gib": 96,
               "chips_per_node": 8,
               "intra_node": {"type": "uniform", "gbps": 250},
               "nics_per_node": 8, "nic_gbps": 25, "mfu": 0.5}],
    "cluster": {"name": "q1-lab", "groups": [{"chip": "CliTest-Q1", "chips": 16}]},
    "gbs_tokens": 1048576
}"#;

#[test]
fn custom_chip_cluster_is_searchable_and_simulatable_from_config_only() {
    let dir = tmp_dir("custom_chip");
    let cfg_path = dir.join("cfg.json");
    std::fs::write(&cfg_path, CUSTOM_CHIP_CONFIG).unwrap();
    let cfg_path = cfg_path.to_str().unwrap();
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();

    // search reads the cluster (and the chip!) from the config alone.
    let stdout = run_ok(h2_bin().args(["search", "--config", cfg_path, "--emit-plan", plan_path]));
    assert!(stdout.contains("CliTest-Q1"), "search output should name the chip:\n{stdout}");

    // The emitted plan is self-contained: simulate needs no --config.
    let stdout = run_ok(h2_bin().args(["simulate", "--plan", plan_path]));
    assert!(stdout.contains("TGS"), "simulate output:\n{stdout}");
    parse_iteration_seconds(&stdout);

    let text = std::fs::read_to_string(plan_path).unwrap();
    assert!(text.contains("CliTest-Q1"), "plan must embed the custom chip:\n{text}");
}

#[test]
fn config_flag_works_across_subcommands() {
    let dir = tmp_dir("config_everywhere");
    let cfg_path = dir.join("cfg.json");
    std::fs::write(&cfg_path, CUSTOM_CHIP_CONFIG).unwrap();
    let cfg_path = cfg_path.to_str().unwrap();

    // profile resolves the config-declared chip by name...
    let stdout = run_ok(h2_bin().args(["profile", "--config", cfg_path, "--chip", "CliTest-Q1"]));
    assert!(stdout.contains("CliTest-Q1"), "profile output:\n{stdout}");
    // ...and lists it alongside the built-ins without --chip.
    let stdout = run_ok(h2_bin().args(["profile", "--config", cfg_path]));
    assert!(stdout.contains("CliTest-Q1") && stdout.contains("Chip-A"));

    // simulate takes its cluster from the config.
    let stdout = run_ok(h2_bin().args(["simulate", "--config", cfg_path]));
    assert!(stdout.contains("q1-lab"), "simulate output:\n{stdout}");

    // comm-bench accepts the same flag (chips register, sweep unaffected).
    let stdout =
        run_ok(h2_bin().args(["comm-bench", "--config", cfg_path, "--max-shift", "10"]));
    assert!(stdout.contains("TCP/DDR"));

    // A missing config file fails loudly everywhere.
    for sub in ["search", "simulate", "profile", "comm-bench", "report"] {
        let out = h2_bin().args([sub, "--config", "/nonexistent/h2.json"]).output().unwrap();
        assert!(!out.status.success(), "{sub} should fail on a missing config");
    }
}

#[test]
fn schedule_flag_pins_search_and_reschedules_plans() {
    let dir = tmp_dir("schedule");
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();

    // Pin the search to the zero-bubble schedule; the emitted plan must
    // carry it.
    run_ok(h2_bin().args([
        "search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1",
        "--schedule", "zbv", "--emit-plan", plan_path,
    ]));
    let plan = ExecutionPlan::load(plan_path).unwrap();
    assert_eq!(plan.strategy.schedule, Schedule::ZeroBubbleV);

    // Simulating the plan reports the schedule it runs under...
    let stdout = run_ok(h2_bin().args(["simulate", "--plan", plan_path]));
    assert!(stdout.contains("zbv"), "simulate output should name the schedule:\n{stdout}");

    // ...and --schedule re-schedules a persisted plan without re-searching.
    let stdout = run_ok(h2_bin().args([
        "simulate", "--plan", plan_path, "--schedule", "1f1b",
    ]));
    assert!(stdout.contains("1f1b"), "override output:\n{stdout}");
    let zbv: f64 = parse_iteration_seconds(
        &run_ok(h2_bin().args(["simulate", "--plan", plan_path])),
    ).parse().unwrap();
    let f1b1: f64 = parse_iteration_seconds(&stdout).parse().unwrap();
    assert!(zbv <= f1b1 * 1.05,
            "zero-bubble {zbv} should not be materially slower than 1F1B {f1b1} \
             on the same plan");

    // A bogus schedule token fails loudly.
    let out = h2_bin()
        .args(["simulate", "--plan", plan_path, "--schedule", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bad --schedule must be rejected");
}

#[test]
fn search_progress_flag_reports_on_stderr_and_is_off_by_default() {
    // --progress: at least the per-stage summary lines land on stderr
    // (periodic lines appear only on long searches), and the searched
    // result is untouched.
    let with = h2_bin()
        .args(["search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1", "--progress"])
        .output()
        .unwrap();
    assert!(with.status.success());
    let stderr = String::from_utf8_lossy(&with.stderr);
    assert!(stderr.contains("[h2 search]"),
            "expected progress lines on stderr:\n{stderr}");
    assert!(stderr.contains("coarse stage") && stderr.contains("refine stage"),
            "expected one summary per stage:\n{stderr}");

    // Off by default: stderr stays silent.
    let without = h2_bin()
        .args(["search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1"])
        .output()
        .unwrap();
    assert!(without.status.success());
    assert!(!String::from_utf8_lossy(&without.stderr).contains("[h2 search]"),
            "progress must be opt-in");

    // Purely observational: the winning strategy line is identical.
    let pick = |out: &std::process::Output| {
        String::from_utf8_lossy(&out.stdout)
            .lines()
            .find(|l| l.starts_with("s_dp"))
            .map(str::to_string)
            .expect("search prints its strategy line")
    };
    assert_eq!(pick(&with), pick(&without));
}

#[test]
fn comm_algo_flag_pins_search_and_overrides_plans() {
    use h2::comm::CommAlgo;
    let dir = tmp_dir("comm_algo");
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();

    // Pin the search to the hierarchical collective; the emitted plan
    // must carry it.
    run_ok(h2_bin().args([
        "search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1",
        "--comm-algo", "hierarchical", "--emit-plan", plan_path,
    ]));
    let plan = ExecutionPlan::load(plan_path).unwrap();
    assert_eq!(plan.strategy.comm_algo, CommAlgo::Hierarchical);

    // Simulating the plan reports the collective it runs...
    let stdout = run_ok(h2_bin().args(["simulate", "--plan", plan_path]));
    assert!(stdout.contains("hierarchical"),
            "simulate output should name the collective:\n{stdout}");

    // ...and --comm-algo re-prices a persisted plan without re-searching.
    let stdout = run_ok(h2_bin().args([
        "simulate", "--plan", plan_path, "--comm-algo", "ring",
    ]));
    assert!(stdout.contains("ring"), "override output:\n{stdout}");
    let hier: f64 = parse_iteration_seconds(
        &run_ok(h2_bin().args(["simulate", "--plan", plan_path])),
    ).parse().unwrap();
    let ring: f64 = parse_iteration_seconds(&stdout).parse().unwrap();
    assert!(hier <= ring * 1.0001,
            "hierarchical {hier} should not lose to the flat ring {ring} \
             on the same plan");

    // A bogus algorithm token fails loudly.
    let out = h2_bin()
        .args(["simulate", "--plan", plan_path, "--comm-algo", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bad --comm-algo must be rejected");
}

/// A machine-readable `<prefix> <value>` line from stdout.
fn parse_line<'a>(stdout: &'a str, prefix: &str) -> &'a str {
    stdout
        .lines()
        .find_map(|l| l.strip_prefix(prefix))
        .unwrap_or_else(|| panic!("no `{prefix}` line in:\n{stdout}"))
        .trim()
}

/// The parity fixture (`common.rs`) as a plan file: 2-stage mixed-vendor
/// pipeline whose Chip-B stage syncs gradients across nodes (so the
/// collective matters) — the same plan the in-process parity suite runs.
fn write_virtual_fixture(path: &str, comm_algo: h2::comm::CommAlgo) {
    common::two_stage_mixed_vendor_plan(Schedule::OneF1B, comm_algo)
        .save(path)
        .unwrap();
}

#[test]
fn train_virtual_honors_the_plan_strategy() {
    use h2::comm::CommAlgo;
    let dir = tmp_dir("train_virtual");
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();
    write_virtual_fixture(plan_path, CommAlgo::Hierarchical);

    // The virtual evaluator runs without artifacts and reports the plan's
    // schedule and collective.
    let stdout = run_ok(h2_bin().args(["train", "--plan", plan_path, "--virtual",
                                       "--steps", "1"]));
    assert!(stdout.contains("hierarchical"),
            "virtual run should name the plan's collective:\n{stdout}");
    assert!(stdout.contains("1f1b"),
            "virtual run should name the plan's schedule:\n{stdout}");
    let hier_comm: f64 = parse_line(&stdout, "virtual_comm_seconds ").parse().unwrap();
    assert!(hier_comm > 0.0);

    // --comm-algo overrides the plan with a visible warning, and the ring
    // must report MORE virtual comm seconds on this node-crossing fixture.
    let out = h2_bin()
        .args(["train", "--plan", plan_path, "--virtual", "--steps", "1",
               "--comm-algo", "ring"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("overrides"),
            "expected an override warning on stderr:\n{stderr}");
    let stdout = String::from_utf8(out.stdout).unwrap();
    let ring_comm: f64 = parse_line(&stdout, "virtual_comm_seconds ").parse().unwrap();
    assert!(hier_comm < ring_comm,
            "hierarchical comm {hier_comm} should beat the flat ring {ring_comm}");

    // --schedule overrides with a warning too.
    let out = h2_bin()
        .args(["train", "--plan", plan_path, "--virtual", "--steps", "1",
               "--schedule", "zbv"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("overrides"));
    assert!(String::from_utf8_lossy(&out.stdout).contains("zbv"));
}

#[test]
fn train_virtual_params_are_identical_across_comm_algos() {
    use h2::comm::CommAlgo;
    let dir = tmp_dir("train_virtual_params");
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();
    write_virtual_fixture(plan_path, CommAlgo::Ring);
    let mut fingerprints = Vec::new();
    for algo in ["ring", "tree", "rhd", "hierarchical", "auto"] {
        let out = h2_bin()
            .args(["train", "--plan", plan_path, "--virtual", "--steps", "2",
                   "--comm-algo", algo])
            .output()
            .unwrap();
        assert!(out.status.success(), "{algo} run failed");
        let stdout = String::from_utf8(out.stdout).unwrap();
        fingerprints.push(parse_line(&stdout, "params_fnv ").to_string());
    }
    assert!(fingerprints.windows(2).all(|w| w[0] == w[1]),
            "final parameters must be bit-identical across comm algos: {fingerprints:?}");
}

#[test]
fn simulate_plan_flag_overrides_still_apply() {
    let dir = tmp_dir("overrides");
    let plan_path = dir.join("plan.json");
    let plan_path = plan_path.to_str().unwrap();
    run_ok(h2_bin().args([
        "search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1", "--emit-plan", plan_path,
    ]));
    let ddr = parse_iteration_seconds(&run_ok(h2_bin().args(["simulate", "--plan", plan_path])));
    let tcp = parse_iteration_seconds(&run_ok(h2_bin().args([
        "simulate", "--plan", plan_path, "--comm", "tcp", "--no-overlap",
    ])));
    let ddr: f64 = ddr.parse().unwrap();
    let tcp: f64 = tcp.parse().unwrap();
    assert!(tcp > ddr, "tcp {tcp} should be slower than ddr {ddr}");
}

#[test]
fn invalid_plan_file_is_rejected_with_structured_errors() {
    let dir = tmp_dir("invalid");
    let plan_path = dir.join("plan.json");
    let plan_path_s = plan_path.to_str().unwrap();
    run_ok(h2_bin().args([
        "search", "--cluster", "A=16,B=16", "--gbs-mtokens", "1", "--emit-plan", plan_path_s,
    ]));
    // Corrupt the layer assignment so validation must fire.
    let text = std::fs::read_to_string(&plan_path).unwrap();
    let mut plan = ExecutionPlan::from_json_str(&text).unwrap();
    plan.strategy.plans[0].layers += 1;
    std::fs::write(&plan_path, plan.to_json_string()).unwrap();

    let out = h2_bin().args(["simulate", "--plan", plan_path_s]).output().unwrap();
    assert!(!out.status.success(), "corrupted plan must be rejected");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("layers"), "error should mention layers:\n{stderr}");
}
