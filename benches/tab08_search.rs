//! Table 8 — HeteroAuto strategy-search overhead on the Exp-A/B/C
//! configurations (two-stage search with 128-chip subgroups), timed against
//! the paper's single-threaded-python budgets.

use h2::auto::{search, SearchConfig};
use h2::costmodel::H2_100B;
use h2::hetero::experiment;
use h2::report::TABLE8_PAPER;
use h2::util::bench::Bench;
use h2::util::table::{fmt_duration, Table};

fn main() {
    let mut t = Table::new(&["experiment", "candidates", "time (ours)", "time (paper)",
                             "speedup"])
        .with_title("Table 8 — strategy-search overhead (two-stage, 128-chip groups)");
    for (exp_name, paper_secs) in TABLE8_PAPER {
        let exp = experiment(exp_name).unwrap();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())
            .expect(exp_name);
        assert!(r.eval.feasible);
        t.row(vec![
            exp_name.to_string(),
            r.candidates_explored.to_string(),
            fmt_duration(r.elapsed_seconds),
            fmt_duration(paper_secs),
            format!("{:.0}x", paper_secs / r.elapsed_seconds),
        ]);
        assert!(r.elapsed_seconds < paper_secs,
                "{exp_name}: search slower than the paper's budget");
    }
    // Beyond Table 8: the 1,280-chip 4-vendor mega cluster (the §4.3.3
    // >1,000-chip headline scenario). The paper reports no search time at
    // this scale, so the row carries our own generous 120 s ceiling — the
    // point is that the two-stage search completes at all, feasibly, in
    // interactive time.
    let mega = experiment("exp-mega").unwrap();
    let r = search(&H2_100B, &mega.cluster, mega.gbs_tokens, &SearchConfig::default())
        .expect("exp-mega");
    assert!(r.eval.feasible);
    assert!(r.elapsed_seconds < 120.0,
            "exp-mega: two-stage search took {:.1}s", r.elapsed_seconds);
    t.row(vec![
        "exp-mega".to_string(),
        r.candidates_explored.to_string(),
        fmt_duration(r.elapsed_seconds),
        "-".to_string(),
        "-".to_string(),
    ]);
    t.print();
    println!("reference points: Metis needs 600s for 64 chips/2 types; Alpa 240min.");

    // Repeated-timing microbench of the most expensive searches: the
    // 4-type Exp-B and the paper-scale mega cluster.
    let exp = experiment("exp-b-1").unwrap();
    let mut b = Bench::new("tab08 search hot path").max_seconds(4.0).min_iters(3);
    b.run("exp-b-1 two-stage search", || {
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())
            .unwrap();
        std::hint::black_box(r.eval.iteration_seconds);
    });
    b.run("exp-mega two-stage search", || {
        let r = search(&H2_100B, &mega.cluster, mega.gbs_tokens, &SearchConfig::default())
            .unwrap();
        std::hint::black_box(r.eval.iteration_seconds);
    });
    b.report();
    println!("OK: Table 8 reproduced (all searches within the paper's budget)");
}
