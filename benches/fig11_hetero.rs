//! Figure 11 — heterogeneous training throughput and HeteroSpeedupRatio
//! for the Table 7 experiment configurations, via HeteroAuto + the
//! discrete-event HeteroPP simulator.

use h2::hetero::ALL_EXPERIMENTS;
use h2::report::{hetero_row, table6_all};
use h2::util::table::{fmt_duration, Table};

fn main() {
    let baselines = table6_all();
    println!("baselines (simulated TGS): {}",
             baselines.iter().map(|b| format!("{}={:.1}", b.kind, b.sim_tgs))
                 .collect::<Vec<_>>().join("  "));

    let mut t = Table::new(&["experiment", "chips", "GBS", "TGS", "HeteroSpeedupRatio",
                             "paper", "search time"])
        .with_title("Fig 11 — heterogeneous setups (HeteroAuto + simulator)");
    let mut measured = Vec::new();
    for exp_name in ALL_EXPERIMENTS {
        let row = hetero_row(exp_name, &baselines).expect(exp_name);
        let exp = h2::hetero::experiment(exp_name).unwrap();
        t.row(vec![
            exp_name.to_string(),
            exp.cluster.total_chips().to_string(),
            format!("{}M", exp.gbs_tokens >> 20),
            format!("{:.1}", row.sim_tgs),
            format!("{:.2}%", row.speedup_ratio),
            row.paper_ratio.map(|p| format!("{p:.2}%")).unwrap_or_else(|| "-".into()),
            fmt_duration(row.search.elapsed_seconds),
        ]);
        measured.push((exp_name, row.speedup_ratio, row.paper_ratio));
    }
    t.print();

    // Shape checks against the paper's headline claims:
    let get = |name: &str| measured.iter().find(|(n, _, _)| *n == name).unwrap().1;
    // 1) summed-GBS configurations achieve SUPERLINEAR speedup (>100%).
    assert!(get("exp-a-2") > 100.0, "exp-a-2 must be superlinear");
    assert!(get("exp-b-2") > 100.0, "exp-b-2 must be superlinear");
    // 2) constant-GBS configurations fall below their summed counterparts.
    assert!(get("exp-a-1") < get("exp-a-2"));
    assert!(get("exp-b-1") < get("exp-b-2"));
    // 3) more chip types (B vs A) lowers the ratio, as in the paper.
    assert!(get("exp-b-1") < get("exp-a-1"));
    assert!(get("exp-b-2") < get("exp-a-2"));
    println!("OK: Fig 11 shape reproduced (superlinear summed-GBS, ordering matches)");
}
