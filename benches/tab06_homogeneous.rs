//! Table 6 — homogeneous 256-chip training baselines for the 100B model:
//! cost-model and simulator TGS vs the paper's measurements, using the
//! paper's own hybrid-parallelism configurations.

use h2::report::table6_all;
use h2::util::table::Table;

fn main() {
    let rows = table6_all();
    let mut t = Table::new(&["chip", "PP", "DP", "TP", "extra",
                             "TGS model", "TGS sim", "TGS paper", "err%"])
        .with_title("Table 6 — homogeneous baselines (256 chips, GBS 2M tokens)");
    for (row, &(_, pp, dp, tp, rec, _)) in rows.iter().zip(&h2::report::TABLE6) {
        let extra = if rec {
            "recompute"
        } else if row.kind == h2::hetero::ChipKind::D {
            "offload"
        } else {
            "-"
        };
        let err = (row.sim_tgs - row.paper_tgs) / row.paper_tgs * 100.0;
        t.row(vec![
            row.kind.to_string(),
            pp.to_string(),
            dp.to_string(),
            tp.to_string(),
            extra.to_string(),
            format!("{:.1}", row.model_tgs),
            format!("{:.1}", row.sim_tgs),
            format!("{:.1}", row.paper_tgs),
            format!("{err:+.1}%"),
        ]);
    }
    t.print();

    // Shape checks: ordering of chips must match the paper.
    let tgs: Vec<f64> = rows.iter().map(|r| r.sim_tgs).collect();
    assert!(tgs[1] > tgs[0], "B must beat A");
    assert!(tgs[2] < tgs[3], "C must be the slowest");
    for row in &rows {
        let rel = (row.sim_tgs - row.paper_tgs).abs() / row.paper_tgs;
        assert!(rel < 0.15, "{}: sim {} vs paper {}", row.kind, row.sim_tgs, row.paper_tgs);
    }
    println!("OK: Table 6 reproduced (every chip within 15%, ordering exact)");
}
