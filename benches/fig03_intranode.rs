//! Figure 3 — intra-node bandwidth performance across the four GPU-server
//! designs: uniform NVLink-class fabrics vs NUMA-split vs PCIe-switch
//! hierarchies, and the TP_MAX each implies.

use h2::hetero::{spec, ChipKind};
use h2::topology::{intra_node_matrix, intra_node_profile};
use h2::util::table::Table;

fn main() {
    let mut t = Table::new(&["server", "chips", "min GB/s", "max GB/s", "uniform?", "TP_MAX"])
        .with_title("Fig 3 — intra-node bandwidth per server design");
    for kind in ChipKind::ALL {
        let s = spec(kind);
        let p = intra_node_profile(&s);
        t.row(vec![
            kind.to_string(),
            s.chips_per_node.to_string(),
            format!("{:.0}", p.min_gbps),
            format!("{:.0}", p.max_gbps),
            if p.uniform { "yes" } else { "no" }.to_string(),
            p.tp_max.to_string(),
        ]);
    }
    t.print();

    // Pairwise matrix for the most hierarchical server (Chip-C).
    let c = spec(ChipKind::C);
    let m = intra_node_matrix(&c);
    println!("\nChip-C pairwise bandwidth (first 8 slots, GB/s):");
    for row in m.iter().take(8) {
        let cells: Vec<String> = row.iter().take(8).map(|b| format!("{b:>4.0}")).collect();
        println!("  {}", cells.join(" "));
    }
    println!("\npaper claim: some servers lack full high-speed intra-node connections,");
    println!("giving non-uniform bandwidth and bounding usable TP size (Obs #2).");

    let a = intra_node_profile(&spec(ChipKind::A));
    let cc = intra_node_profile(&c);
    assert!(a.uniform && !cc.uniform);
    assert!(cc.tp_max < a.tp_max);
    println!("OK: A uniform (TP_MAX {}), C hierarchical (TP_MAX {})", a.tp_max, cc.tp_max);
}
