//! Figure 12 — small-scale end-to-end training of the 8-decoder-layer
//! model, with and without device-direct RDMA (DDR): REAL pipeline runs
//! (PP=2, uniform 1F1B; TP=4 and DP=2 of the paper's setup are modeled in
//! the communication volumes) on two heterogeneous server types.
//!
//! Reported per-iteration time = measured stage compute + the DiComm
//! model's exposed wire time, mirroring the paper's bar chart. Steps
//! default to 3 for bench time (H2_FIG12_STEPS to override).

use h2::comm::CommMode;
use h2::coordinator::{train, StagePlan, TrainConfig};
use h2::hetero::ChipKind;
use h2::runtime::Runtime;
use h2::util::table::Table;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let steps: usize = std::env::var("H2_FIG12_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let rt = Runtime::open("artifacts").unwrap();

    // The paper's Fig 12: A+B, A+C, B+C pairings of two 8-chip servers.
    let pairs = [
        (ChipKind::A, ChipKind::B),
        (ChipKind::A, ChipKind::C),
        (ChipKind::B, ChipKind::C),
    ];
    let mut t = Table::new(&["servers", "TCP iter (s)", "DDR iter (s)", "DDR speedup"])
        .with_title("Fig 12 — 8-layer model end-to-end, CPU-mediated TCP vs DDR");
    for (c1, c2) in pairs {
        let stages = vec![
            StagePlan { prefix: "first_l4".into(), chip: c1 },
            StagePlan { prefix: "last_l4".into(), chip: c2 },
        ];
        let mut cfg = TrainConfig::quick("h2_fig12", stages, 2, 4, steps);
        cfg.fine_overlap = false; // the paper's Fig 12 uses uniform 1F1B
        cfg.log_every = 0;
        cfg.comm = CommMode::TcpCpu;
        let tcp = train(&rt, &cfg).unwrap();
        cfg.comm = CommMode::DeviceDirect;
        let ddr = train(&rt, &cfg).unwrap();

        // Identical numerics in both arms (comm strategy must not change math).
        for (a, b) in tcp.losses.iter().zip(&ddr.losses) {
            assert!((a - b).abs() < 1e-9, "losses diverged between comm modes");
        }
        let iter_tcp = (tcp.wall_seconds + tcp.virtual_comm_seconds * 2.0) / steps as f64;
        let iter_ddr = (ddr.wall_seconds + ddr.virtual_comm_seconds * 2.0) / steps as f64;
        // The wall components are noisy on a shared CPU; the comm component
        // is the modeled difference. Report both and check the ordering on
        // the comm-only numbers.
        t.row(vec![
            format!("{c1}+{c2}"),
            format!("{iter_tcp:.3} (comm {:.3})", tcp.virtual_comm_seconds / steps as f64),
            format!("{iter_ddr:.3} (comm {:.3})", ddr.virtual_comm_seconds / steps as f64),
            format!("{:.2}x", tcp.virtual_comm_seconds / ddr.virtual_comm_seconds.max(1e-12)),
        ]);
        assert!(tcp.virtual_comm_seconds > ddr.virtual_comm_seconds,
                "{c1}+{c2}: DDR must reduce comm time");
    }
    t.print();
    println!("paper claim: DDR consistently outperforms CPU-mediated TCP across");
    println!("all chip combinations (largest gap when Chip-C is involved).");
    println!("OK: Fig 12 reproduced on the real training pipeline");
}
