//! §Perf — hot-path micro-benchmarks for the L3 coordinator substrates:
//! the simulator inner loop, HeteroAuto search, ring allreduce, the fabric
//! send/recv path and the JSON/manifest parser. Tracked in EXPERIMENTS.md
//! §Perf (before/after per optimization).

use h2::auto::{search, SearchConfig};
use h2::comm::collectives::ring_allreduce;
use h2::comm::fabric;
use h2::costmodel::{GroupPlan, Schedule, Strategy, H2_100B};
use h2::hetero::{experiment, homogeneous_baseline, ChipKind};
use h2::sim::{simulate_iteration, SimOptions};
use h2::util::bench::Bench;
use h2::util::json::Value;
use h2::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let mut b = Bench::new("h2 hot paths").max_seconds(2.5);

    // Simulator: the Fig 11 inner loop (one full 1F1B iteration at scale).
    let exp = homogeneous_baseline(ChipKind::A);
    let groups = exp.cluster.groups_by_memory_desc();
    let mut strategy = Strategy {
        s_dp: 4,
        micro_batches: 128,
        schedule: Schedule::OneF1B,
        plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
    };
    b.run("sim: 16-stage x 128-micro 1F1B", || {
        let r = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        std::hint::black_box(r.iteration_seconds);
    });

    // The schedule-aware issue orders (interleaved chunking, zero-bubble
    // greedy fill) are costlier inner loops — track them next to 1F1B.
    strategy.schedule = Schedule::Interleaved { virtual_stages: 2 };
    b.run("sim: 16-stage x 128-micro interleaved:2", || {
        let r = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        std::hint::black_box(r.iteration_seconds);
    });
    strategy.schedule = Schedule::ZeroBubbleV;
    b.run("sim: 16-stage x 128-micro zero-bubble", || {
        let r = simulate_iteration(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        std::hint::black_box(r.iteration_seconds);
    });

    // HeteroAuto: the coarse (stage-1) search for Exp-A.
    let expa = experiment("exp-a-1").unwrap();
    let coarse = SearchConfig { two_stage: false, ..Default::default() };
    b.run("search: exp-a-1 coarse", || {
        let r = search(&H2_100B, &expa.cluster, expa.gbs_tokens, &coarse).unwrap();
        std::hint::black_box(r.candidates_explored);
    });

    // DiComm collectives: 8-rank allreduce over 1M floats.
    let mut rng = Rng::new(7);
    let bufs: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..1_000_000).map(|_| rng.f32()).collect())
        .collect();
    b.run("allreduce: 8 ranks x 4MB", || {
        let mut work = bufs.clone();
        let c = ring_allreduce(&mut work, &|bytes| 1e-6 + bytes as f64 / 25e9);
        std::hint::black_box(c.seconds);
    });

    // Fabric: send/recv of a 1MB activation (the pipeline hand-off path).
    b.run("fabric: 1MB send+recv", || {
        let mut eps = fabric::fabric(2, Arc::new(|_, _, _| 1e-6));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 0, vec![1.0f32; 262_144]).unwrap();
        std::hint::black_box(e0.recv(1, 0).unwrap().len());
    });

    // Manifest/JSON parse (startup path).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let text = std::fs::read_to_string("artifacts/manifest.json").unwrap();
        b.run("json: parse manifest", || {
            std::hint::black_box(Value::parse(&text).unwrap());
        });
    }

    b.report();
}
