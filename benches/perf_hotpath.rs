//! §Perf — hot-path micro-benchmarks for the L3 coordinator substrates:
//! the simulator inner loop, HeteroAuto search, the DiComm collective
//! library (ring and hierarchical allreduces, closed-form pricing), the
//! fabric send/recv path and the JSON/manifest parser. Tracked in
//! EXPERIMENTS.md §Perf (before/after per optimization). The simulator
//! benches run as engine/reference pairs — the flat-arena engine next to
//! the pre-refactor executor on identical inputs — and the old-vs-new
//! speedup per schedule is printed after the report.
//!
//! Doubles as the CI perf-regression guard:
//!
//! ```bash
//! cargo bench --bench perf_hotpath -- --baseline BENCH_baseline.json
//! cargo bench --bench perf_hotpath -- --write-baseline BENCH_baseline.json
//! ```
//!
//! `--baseline` compares each benchmark's p50 against the checked-in
//! per-bench budget and exits non-zero when one exceeds `threshold x`
//! budget (the file's `threshold` key, a deliberately generous 2x by
//! default — the budgets are ceilings for slow CI runners, not measured
//! laptop numbers). `--write-baseline` snapshots the current p50s
//! instead, for regenerating the file on a reference machine.

use h2::auto::{replan, search, search_with_cache, ClusterDelta, ReplanOptions, SearchConfig};
use h2::comm::collectives::{alltoall, hierarchical_allreduce, ring_allreduce};
use h2::comm::{allreduce_cost, fabric, AllToAllAlgo, CommAlgo, CommTopology, LinkTime};
use h2::costmodel::{GroupPlan, ProfileCache, Schedule, Strategy, H2_100B};
use h2::hetero::{experiment, homogeneous_baseline, spec, ChipKind};
use h2::sim::{reference, SimEngine, SimOptions};
use h2::topology::NicAssignment;
use h2::util::bench::Bench;
use h2::util::cli::Args;
use h2::util::json::{self, Value};
use h2::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    // min_iters(5): the mega-cluster searches cost whole seconds per
    // iteration — five samples bound their wall clock while the fast
    // benches still collect thousands inside the per-case budget.
    let mut b = Bench::new("h2 hot paths").max_seconds(2.5).min_iters(5);

    // Simulator: the Fig 11 inner loop (one full iteration at scale) on
    // the arena engine, paired with the pre-arena reference executor on
    // the same inputs — the differential suite proves the outputs are
    // bit-identical, this pair proves the rewrite actually paid off (the
    // old-vs-new ratio is printed after the report).
    let exp = homogeneous_baseline(ChipKind::A);
    let groups = exp.cluster.groups_by_memory_desc();
    let sim_pairs = [
        (
            "sim: 16-stage x 128-micro 1F1B",
            "sim-reference: 16-stage x 128-micro 1F1B",
            Schedule::OneF1B,
        ),
        (
            "sim: 16-stage x 128-micro interleaved:2",
            "sim-reference: 16-stage x 128-micro interleaved:2",
            Schedule::Interleaved { virtual_stages: 2 },
        ),
        (
            "sim: 16-stage x 128-micro zero-bubble",
            "sim-reference: 16-stage x 128-micro zero-bubble",
            Schedule::ZeroBubbleV,
        ),
    ];
    for &(label, ref_label, schedule) in &sim_pairs {
        let strategy = Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 128,
            schedule,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
        };
        let mut eng = SimEngine::new(&H2_100B, &groups, &strategy, 4096, &SimOptions::default());
        b.run(label, || {
            let r = eng.run();
            std::hint::black_box(r.iteration_seconds);
        });
        b.run(ref_label, || {
            let r = reference::simulate_iteration_reference(
                &H2_100B,
                &groups,
                &strategy,
                4096,
                &SimOptions::default(),
            );
            std::hint::black_box(r.iteration_seconds);
        });
    }

    // HeteroAuto: the coarse (stage-1) search for Exp-A.
    let expa = experiment("exp-a-1").unwrap();
    let coarse = SearchConfig { two_stage: false, ..Default::default() };
    b.run("search: exp-a-1 coarse", || {
        let r = search(&H2_100B, &expa.cluster, expa.gbs_tokens, &coarse).unwrap();
        std::hint::black_box(r.candidates_explored);
    });

    // HeteroAuto at paper scale: the 1,280-chip 4-vendor mega cluster
    // (§4.3.3's >1,000-chip claim) — the coarse pass alone and the full
    // two-stage refinement, whose 128-chip subgroup split fans the DFS out
    // to ten groups. These lean on the profile cache, the incremental
    // sharding refinement, the bubble/DP-sync bound terms and the
    // work-queue split all at once.
    let mega = experiment("exp-mega").unwrap();
    b.run("search: mega-cluster coarse", || {
        let r = search(&H2_100B, &mega.cluster, mega.gbs_tokens, &coarse).unwrap();
        std::hint::black_box(r.candidates_explored);
    });
    let two_stage = SearchConfig::default();
    b.run("search: mega-cluster two-stage", || {
        let r = search(&H2_100B, &mega.cluster, mega.gbs_tokens, &two_stage).unwrap();
        std::hint::black_box(r.eval.iteration_seconds);
    });

    // Elastic re-plan: exp-mega loses one node and re-plans over the
    // profile cache the incumbent search warmed — the recovery half of
    // the restart-vs-recovery margin, so it must stay far cheaper than
    // the cold two-stage search above. Victim and mode are fixed in
    // setup: the first node (largest-first, TP >= 2 preferred) whose
    // pipeline-preserving re-plan succeeds, else a full re-plan.
    let cache = ProfileCache::new();
    let warm =
        search_with_cache(&H2_100B, &mega.cluster, mega.gbs_tokens, &two_stage, &cache).unwrap();
    let incumbent = warm.into_plan(&H2_100B, &mega.cluster, mega.gbs_tokens);
    let mut victims: Vec<_> =
        incumbent.stage_groups.iter().zip(&incumbent.strategy.plans).collect();
    victims.sort_by_key(|(g, p)| (p.s_tp < 2, std::cmp::Reverse(g.n_chips)));
    let keep = ReplanOptions::default();
    let mut case = None;
    for (g, _) in &victims {
        let delta = ClusterDelta::exclude(g.spec.kind, g.spec.chips_per_node);
        if replan(&incumbent, &delta, &cache, &keep).is_ok() {
            case = Some((delta, keep));
            break;
        }
    }
    let (delta, ropts) = case.unwrap_or_else(|| {
        let g = victims[0].0;
        (
            ClusterDelta::exclude(g.spec.kind, g.spec.chips_per_node),
            ReplanOptions { keep_pipeline: false, ..keep },
        )
    });
    b.run("replan: exp-mega after chip loss", || {
        let out = replan(&incumbent, &delta, &cache, &ropts).unwrap();
        std::hint::black_box(out.plan.plan_epoch);
    });

    // The full-cluster simulation of the incumbent mega plan itself: the
    // 1,280-chip iteration the re-planner scores candidates with, on the
    // warm arena engine (arenas sized once, zero per-op allocation).
    let mut mega_eng = SimEngine::for_plan(&incumbent);
    b.run("sim: exp-mega full-cluster", || {
        let r = mega_eng.run();
        std::hint::black_box(r.iteration_seconds);
    });

    // Fleet: the pinned contrast trace packed onto exp-mega under
    // priority-with-backfill — a whole fleet run per iteration (two
    // whole-cluster 100B solves, a burst of eight small 20B placements,
    // preempt-by-resize, and the batched engine-pool pricing). This is
    // the `h2 fleet --exp exp-mega --trace pinned` hot path end to end;
    // EXPERIMENTS.md §Fleet tracks it.
    let fleet_trace = h2::fleet::JobTrace::pinned(mega.cluster.total_chips());
    let fleet_opts = h2::fleet::FleetOptions {
        policy: h2::fleet::Policy::PriorityBackfill,
        ..Default::default()
    };
    b.run("fleet: exp-mega pinned trace", || {
        let tl = h2::fleet::run(&mega.cluster, &fleet_trace, &fleet_opts).unwrap();
        std::hint::black_box(tl.metrics.p99_wait_seconds);
    });

    // Fleet under faults: the same pinned trace with the pinned cluster
    // fault plan and the graceful-degradation cascade — adds the fault
    // projection, an in-place re-plan, a requeue-from-checkpoint, and
    // the recovery-ledger accounting on top of the healthy run above.
    // The healthy prerun that seeds the fault plan runs once in setup.
    let fault_base = h2::fleet::FleetOptions {
        policy: h2::fleet::Policy::Fifo,
        checkpoint_every: 10,
        ..Default::default()
    };
    let fleet_healthy = h2::fleet::run(&mega.cluster, &fleet_trace, &fault_base).unwrap();
    let fleet_faults =
        h2::fleet::ClusterFaultPlan::pinned_for(&mega.cluster, &fleet_healthy).unwrap();
    let faulty_opts =
        h2::fleet::FleetOptions { faults: Some(fleet_faults), ..fault_base };
    b.run("fleet: exp-mega faulty trace", || {
        let tl = h2::fleet::run(&mega.cluster, &fleet_trace, &faulty_opts).unwrap();
        std::hint::black_box(tl.metrics.goodput_fraction);
    });

    // DiComm collectives: 8-rank allreduce over 1M floats, flat ring vs
    // the two-level hierarchical schedule (2 nodes x 4 ranks). Link times
    // come from the Chip-B server spec via the DP-group topology (TP 2
    // co-locates 4 replicas per 8-chip node) — the same derivation the
    // coordinator's DpGroup uses, not hardwired hop constants.
    let mut rng = Rng::new(7);
    let bufs: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..1_000_000).map(|_| rng.f32()).collect())
        .collect();
    let dp_topo = CommTopology::dp_group(&spec(ChipKind::B), 8, 2, NicAssignment::Affinity);
    let intra_hop = move |bytes: usize| dp_topo.intra.time(bytes);
    let inter_hop = move |bytes: usize| dp_topo.inter.time(bytes);
    b.run("allreduce: 8 ranks x 4MB", || {
        let mut work = bufs.clone();
        let c = ring_allreduce(&mut work, &inter_hop);
        std::hint::black_box(c.seconds);
    });
    b.run("allreduce: hierarchical 2x4 ranks x 4MB", || {
        let mut work = bufs.clone();
        let c = hierarchical_allreduce(&mut work, dp_topo.node_group(), &intra_hop, &inter_hop);
        std::hint::black_box(c.seconds);
    });

    // All-to-all: the exp-moe MoE dispatch payload over an 8-way EP group
    // on Chip-A servers — TP 8 co-locates 2 replicas per 16-chip node, so
    // the group spans 4 nodes and the hierarchical two-level exchange has
    // real structure for Auto to weigh against pairwise. This is the
    // per-layer hot collective the §4.3.2 MoE term prices twice per
    // microbatch (dispatch + combine).
    let ep_topo = CommTopology::dp_group(&spec(ChipKind::A), 8, 8, NicAssignment::Affinity);
    let ep_intra = |bytes: usize| ep_topo.intra.time(bytes);
    let ep_inter = |bytes: usize| ep_topo.inter.time(bytes);
    let moe_bufs: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..1_000_000).map(|_| rng.f32()).collect())
        .collect();
    b.run("alltoall: exp-moe", || {
        let (out, c) =
            alltoall(AllToAllAlgo::Auto, &moe_bufs, ep_topo.ranks_per_node, &ep_intra, &ep_inter);
        std::hint::black_box((out[0][0], c.seconds));
    });

    // Closed-form collective pricing + auto selection (the cost-model and
    // search inner loop — must stay trivially cheap).
    let topo = CommTopology {
        n_ranks: 16,
        ranks_per_node: 8,
        intra: LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 },
        inter: LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 },
    };
    b.run("comm: auto allreduce cost x 1k", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += allreduce_cost(CommAlgo::Auto, 1 << (10 + i % 16), &topo).seconds;
        }
        std::hint::black_box(acc);
    });

    // Fabric: send/recv of a 1MB activation (the pipeline hand-off path).
    b.run("fabric: 1MB send+recv", || {
        let mut eps = fabric::fabric(2, Arc::new(|_, _, _| 1e-6));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 0, vec![1.0f32; 262_144]).unwrap();
        std::hint::black_box(e0.recv(1, 0).unwrap().len());
    });

    // Manifest/JSON parse (startup path).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let text = std::fs::read_to_string("artifacts/manifest.json").unwrap();
        b.run("json: parse manifest", || {
            std::hint::black_box(Value::parse(&text).unwrap());
        });
    }

    b.report();

    // Old-vs-new: the arena engine against the reference executor it
    // replaced, from the p50s measured above.
    let p50 = |l: &str| b.rows().iter().find(|(n, _)| n == l).map(|(_, s)| s.p50);
    for &(label, ref_label, _) in &sim_pairs {
        if let (Some(new), Some(old)) = (p50(label), p50(ref_label)) {
            println!(
                "sim speedup {label}: {:.1}x (reference p50 {old:.6}s / engine p50 {new:.6}s)",
                old / new
            );
        }
    }

    if let Some(path) = args.get("write-baseline") {
        write_baseline(&b, path);
    }
    if let Some(path) = args.get("baseline") {
        check_baseline(&b, path);
    }
}

/// Snapshot the current p50s as a budget file (regeneration path).
fn write_baseline(b: &Bench, path: &str) {
    let mut marks = Vec::new();
    for (label, s) in b.rows() {
        marks.push((label.as_str(), json::num(s.p50)));
    }
    let v = json::obj(vec![
        (
            "_comment",
            json::s(
                "Per-bench p50 budgets (seconds/iter) for the CI perf guard; \
                 regenerate with: cargo bench --bench perf_hotpath -- \
                 --write-baseline BENCH_baseline.json",
            ),
        ),
        ("threshold", json::num(2.0)),
        ("benchmarks", json::obj(marks)),
    ]);
    std::fs::write(path, v.to_string_pretty()).expect("writing baseline");
    println!("wrote baseline with {} benchmarks to {path}", b.rows().len());
}

/// Compare the run against the checked-in budgets; exit 1 on regression.
fn check_baseline(b: &Bench, path: &str) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading baseline {path}: {e}"));
    let v = Value::parse(&text).expect("parsing baseline JSON");
    let threshold = v.opt("threshold").map(|t| t.num().unwrap()).unwrap_or(2.0);
    let marks = v.get("benchmarks").and_then(|m| m.obj().cloned()).expect("`benchmarks` object");
    let mut failures = Vec::new();
    for (label, budget) in &marks {
        let budget = budget.num().expect("budget seconds");
        match b.rows().iter().find(|(l, _)| l == label) {
            Some((_, s)) if s.p50 > threshold * budget => {
                failures.push(format!(
                    "  {label}: p50 {:.6}s > {threshold}x budget {budget:.6}s",
                    s.p50
                ));
            }
            Some(_) => {}
            // A renamed/removed bench is a warning, not a failure — update
            // the baseline in the same change that renames it.
            None => eprintln!("warning: baseline entry `{label}` did not run"),
        }
    }
    for (label, _) in b.rows() {
        if !marks.contains_key(label) {
            eprintln!("warning: benchmark `{label}` has no baseline budget");
        }
    }
    if failures.is_empty() {
        println!("perf guard OK: {} benchmarks within {threshold}x budgets", marks.len());
    } else {
        eprintln!("perf regressions against {path}:");
        for f in &failures {
            eprintln!("{f}");
        }
        std::process::exit(1);
    }
}
