//! Table 3 — NIC affinity vs non-affinity throughput on heterogeneous
//! servers: 8 chips concurrently communicating, 64 MiB messages.

use h2::hetero::{spec, ChipKind};
use h2::topology::{flow_bandwidth_gbps, NicAssignment};
use h2::util::table::Table;

fn main() {
    let rows = [
        (ChipKind::A, ChipKind::B, 5.51, 9.56, 73.5),
        (ChipKind::B, ChipKind::D, 5.23, 9.91, 89.5),
    ];
    let mut t = Table::new(&["chips", "non-affinity (GB/s)", "affinity (GB/s)",
                             "improvement", "paper"])
        .with_title("Table 3 — per-flow throughput, 8 chips concurrent, 64MiB messages");
    for (src, dst, p_non, p_aff, p_imp) in rows {
        let s = spec(src);
        let d = spec(dst);
        let non = flow_bandwidth_gbps(&s, &d, NicAssignment::NonAffinity);
        let aff = flow_bandwidth_gbps(&s, &d, NicAssignment::Affinity);
        let imp = (aff - non) / non * 100.0;
        t.row(vec![
            format!("{src} -> {dst}"),
            format!("{non:.2} (paper {p_non:.2})"),
            format!("{aff:.2} (paper {p_aff:.2})"),
            format!("{imp:.1}%"),
            format!("{p_imp:.1}%"),
        ]);
        assert!((aff - p_aff).abs() < 0.15, "{src}->{dst} affinity {aff} vs paper {p_aff}");
        assert!((non - p_non).abs() < 0.15, "{src}->{dst} non-affinity {non} vs paper {p_non}");
    }
    t.print();

    // Full cross-product for reference.
    let mut x = Table::new(&["src\\dst", "A", "B", "C", "D"])
        .with_title("\nAll pairs, affinity mode (GB/s per flow)");
    for src in ChipKind::ALL {
        let mut cells = vec![src.to_string()];
        for dst in ChipKind::ALL {
            let bw = flow_bandwidth_gbps(&spec(src), &spec(dst), NicAssignment::Affinity);
            cells.push(format!("{bw:.2}"));
        }
        x.row(cells);
    }
    x.print();
    println!("OK: Table 3 reproduced");
}
