//! Table 9 — ablation variants for large-scale heterogeneous training on
//! the Exp-C-1 configuration: relative iteration time of removing each H2
//! component (DDR, HeteroPP non-uniform sharding, SR&AG resharding,
//! fine-grained overlap), plus the pipeline-schedule axis (1F1B vs
//! interleaved vs zero-bubble) that the paper's single-α cost model could
//! not measure — each schedule runs its own issue order in the simulator.

use h2::costmodel::Schedule;
use h2::report::{schedule_axis, table9_ablation};
use h2::util::table::Table;

fn main() {
    let rows = table9_ablation().expect("ablation");
    let mut t = Table::new(&["variant", "relative iter time", "paper"])
        .with_title("Table 9 — ablations on Exp-C-1 (100% = full H2 system)");
    for r in &rows {
        t.row(vec![
            r.label.to_string(),
            format!("{:.1}%", r.relative_percent),
            format!("{:.1}%", r.paper_percent),
        ]);
    }
    t.print();

    // Shape checks: every ablation hurts; uniform 1F1B hurts the most
    // (the paper's dominant factor), overlap the least.
    for r in &rows[1..] {
        assert!(r.relative_percent > 100.0, "{} should hurt", r.label);
    }
    let uniform = rows.iter().find(|r| r.label.contains("Uniform")).unwrap();
    let overlap = rows.iter().find(|r| r.label.contains("overlap")).unwrap();
    for r in &rows[1..] {
        assert!(uniform.relative_percent >= r.relative_percent - 1e-9,
                "uniform 1F1B must be the worst variant");
    }
    assert!(overlap.relative_percent <= uniform.relative_percent);
    println!("OK: Table 9 ordering reproduced (uniform 1F1B worst, overlap mildest)");

    // Schedule axis on the same cluster: HeteroAuto pinned to each
    // schedule, winner simulated with its real issue order. Relative
    // iteration time against the 1F1B winner (<100% = faster).
    let axis = schedule_axis("exp-c-1").expect("schedule axis");
    let f1b1 = axis
        .iter()
        .find(|r| r.schedule == Schedule::OneF1B)
        .and_then(|r| r.iteration_seconds)
        .expect("1F1B must be feasible on Exp-C-1");
    let mut t = Table::new(&["schedule", "iteration", "vs 1F1B", "TGS"])
        .with_title("Schedule axis — Exp-C-1 (simulated, searched per schedule)");
    for r in &axis {
        t.row(vec![
            r.schedule.to_string(),
            r.iteration_seconds.map(|s| format!("{s:.3}s")).unwrap_or("infeasible".into()),
            r.iteration_seconds.map(|s| format!("{:.1}%", s / f1b1 * 100.0))
                .unwrap_or("-".into()),
            r.tgs.map(|x| format!("{x:.1}")).unwrap_or("-".into()),
        ]);
    }
    t.print();

    // The zero-bubble schedule shares 1F1B's memory envelope and drops the
    // bubble term, so its searched-and-simulated result must not lose.
    let zbv = axis
        .iter()
        .find(|r| r.schedule == Schedule::ZeroBubbleV)
        .and_then(|r| r.iteration_seconds)
        .expect("zbv must be feasible wherever 1F1B is");
    assert!(zbv <= f1b1 * 1.05, "zbv {zbv} vs 1f1b {f1b1}");
    println!("OK: schedule axis measured (zbv within/below the 1F1B time)");
}
