//! Table 9 — ablation variants for large-scale heterogeneous training on
//! the Exp-C-1 configuration: relative iteration time of removing each H2
//! component (DDR, HeteroPP non-uniform sharding, SR&AG resharding,
//! fine-grained overlap).

use h2::report::table9_ablation;
use h2::util::table::Table;

fn main() {
    let rows = table9_ablation().expect("ablation");
    let mut t = Table::new(&["variant", "relative iter time", "paper"])
        .with_title("Table 9 — ablations on Exp-C-1 (100% = full H2 system)");
    for r in &rows {
        t.row(vec![
            r.label.to_string(),
            format!("{:.1}%", r.relative_percent),
            format!("{:.1}%", r.paper_percent),
        ]);
    }
    t.print();

    // Shape checks: every ablation hurts; uniform 1F1B hurts the most
    // (the paper's dominant factor), overlap the least.
    for r in &rows[1..] {
        assert!(r.relative_percent > 100.0, "{} should hurt", r.label);
    }
    let uniform = rows.iter().find(|r| r.label.contains("Uniform")).unwrap();
    let overlap = rows.iter().find(|r| r.label.contains("overlap")).unwrap();
    for r in &rows[1..] {
        assert!(uniform.relative_percent >= r.relative_percent - 1e-9,
                "uniform 1F1B must be the worst variant");
    }
    assert!(overlap.relative_percent <= uniform.relative_percent);
    println!("OK: Table 9 ordering reproduced (uniform 1F1B worst, overlap mildest)");
}
