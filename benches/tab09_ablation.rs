//! Table 9 — ablation variants for large-scale heterogeneous training on
//! the Exp-C-1 configuration: relative iteration time of removing each H2
//! component (DDR, HeteroPP non-uniform sharding, SR&AG resharding,
//! fine-grained overlap), plus two axes the paper's tables could not
//! measure — the pipeline schedule (each variant runs its own issue order
//! in the simulator) and the DiComm collective algorithm (flat ring vs
//! tree vs halving-doubling vs hierarchical vs the auto selector).

use h2::comm::CommAlgo;
use h2::costmodel::Schedule;
use h2::report::{comm_algo_axis, schedule_axis, table9_ablation};
use h2::util::table::Table;

fn main() {
    let rows = table9_ablation().expect("ablation");
    let mut t = Table::new(&["variant", "relative iter time", "paper"])
        .with_title("Table 9 — ablations on Exp-C-1 (100% = full H2 system)");
    for r in &rows {
        t.row(vec![
            r.label.to_string(),
            format!("{:.1}%", r.relative_percent),
            format!("{:.1}%", r.paper_percent),
        ]);
    }
    t.print();

    // Shape checks: every ablation hurts; uniform 1F1B hurts the most
    // (the paper's dominant factor), overlap the least.
    for r in &rows[1..] {
        assert!(r.relative_percent > 100.0, "{} should hurt", r.label);
    }
    let uniform = rows.iter().find(|r| r.label.contains("Uniform")).unwrap();
    let overlap = rows.iter().find(|r| r.label.contains("overlap")).unwrap();
    for r in &rows[1..] {
        assert!(uniform.relative_percent >= r.relative_percent - 1e-9,
                "uniform 1F1B must be the worst variant");
    }
    assert!(overlap.relative_percent <= uniform.relative_percent);
    println!("OK: Table 9 ordering reproduced (uniform 1F1B worst, overlap mildest)");

    // Schedule axis on the same cluster: HeteroAuto pinned to each
    // schedule, winner simulated with its real issue order. Relative
    // iteration time against the 1F1B winner (<100% = faster).
    let axis = schedule_axis("exp-c-1").expect("schedule axis");
    let f1b1 = axis
        .iter()
        .find(|r| r.schedule == Schedule::OneF1B)
        .and_then(|r| r.iteration_seconds)
        .expect("1F1B must be feasible on Exp-C-1");
    let mut t = Table::new(&["schedule", "iteration", "vs 1F1B", "TGS"])
        .with_title("Schedule axis — Exp-C-1 (simulated, searched per schedule)");
    for r in &axis {
        t.row(vec![
            r.schedule.to_string(),
            r.iteration_seconds.map(|s| format!("{s:.3}s"))
                .unwrap_or_else(|| "infeasible".into()),
            r.iteration_seconds.map(|s| format!("{:.1}%", s / f1b1 * 100.0))
                .unwrap_or_else(|| "-".into()),
            r.tgs.map(|x| format!("{x:.1}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    // The zero-bubble schedule shares 1F1B's memory envelope and drops the
    // bubble term, so its searched-and-simulated result must not lose.
    let zbv = axis
        .iter()
        .find(|r| r.schedule == Schedule::ZeroBubbleV)
        .and_then(|r| r.iteration_seconds)
        .expect("zbv must be feasible wherever 1F1B is");
    assert!(zbv <= f1b1 * 1.05, "zbv {zbv} vs 1f1b {f1b1}");
    println!("OK: schedule axis measured (zbv within/below the 1F1B time)");

    // Comm-algo axis on the same cluster: HeteroAuto pinned to 1F1B and to
    // each DiComm collective in turn, winner simulated with its real issue
    // order. Relative iteration time against the flat-ring winner.
    let axis = comm_algo_axis("exp-c-1").expect("comm-algo axis");
    let ring = axis
        .iter()
        .find(|r| r.algo == CommAlgo::Ring)
        .and_then(|r| r.iteration_seconds)
        .expect("flat ring must be feasible on Exp-C-1");
    let mut t = Table::new(&["comm algo", "iteration", "vs ring", "TGS"])
        .with_title("Comm-algo axis — Exp-C-1 (simulated, searched per algorithm)");
    for r in &axis {
        t.row(vec![
            r.algo.to_string(),
            r.iteration_seconds.map(|s| format!("{s:.3}s"))
                .unwrap_or_else(|| "infeasible".into()),
            r.iteration_seconds.map(|s| format!("{:.1}%", s / ring * 100.0))
                .unwrap_or_else(|| "-".into()),
            r.tgs.map(|x| format!("{x:.1}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    // The hierarchical collective and the auto selector must not lose to
    // the flat ring (small slack: each pin may search a slightly
    // different strategy shape).
    let hier = axis
        .iter()
        .find(|r| r.algo == CommAlgo::Hierarchical)
        .and_then(|r| r.iteration_seconds)
        .expect("hierarchical must be feasible wherever ring is");
    let auto = axis
        .iter()
        .find(|r| r.algo == CommAlgo::Auto)
        .and_then(|r| r.iteration_seconds)
        .expect("auto must be feasible wherever ring is");
    assert!(hier <= ring * 1.02, "hier {hier} vs ring {ring}");
    assert!(auto <= ring * 1.02, "auto {auto} vs ring {ring}");
    println!("OK: comm-algo axis measured (hierarchical/auto within the ring time)");
}
