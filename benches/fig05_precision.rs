//! Figure 5 / Table 1 — DiTorch precision alignment: train the same model
//! on each simulated vendor stack (chips A–D) and on the A100 reference,
//! then compare the loss curves with the Mean Relative Error criterion
//! (aligned iff MRE < 1.5%).
//!
//! The paper uses a 20B model for 300 iterations; on this CPU testbed the
//! same REAL training pipeline runs at h2_tiny scale. Steps default to 60
//! for bench time; set H2_PRECISION_STEPS=300 for the full paper protocol
//! (recorded in EXPERIMENTS.md).

use h2::coordinator::{train, StagePlan, TrainConfig};
use h2::hetero::ChipKind;
use h2::precision::{check_alignment, MRE_THRESHOLD};
use h2::runtime::Runtime;
use h2::util::table::Table;

const PAPER_MRE: [(ChipKind, f64); 4] = [
    (ChipKind::A, 0.391),
    (ChipKind::B, 0.477),
    (ChipKind::C, 0.584),
    (ChipKind::D, 1.215),
];

fn stages(chip: ChipKind) -> Vec<StagePlan> {
    vec![
        StagePlan { prefix: "first_l2".into(), chip },
        StagePlan { prefix: "last_l2".into(), chip },
    ]
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let steps: usize = std::env::var("H2_PRECISION_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let rt = Runtime::open("artifacts").unwrap();

    let mut cfg = TrainConfig::quick("h2_tiny", stages(ChipKind::A100), 1, 2, steps);
    cfg.perturb = true;
    cfg.log_every = 0;
    cfg.lr = 2e-3;
    eprintln!("[fig05] A100 reference run ({steps} steps)...");
    let reference = train(&rt, &cfg).unwrap();

    let mut t = Table::new(&["chip", "MRE (ours)", "MRE (paper)", "< 1.5%?"])
        .with_title(&format!("Fig 5 / Table 1 — precision alignment over {steps} iterations"));
    for (chip, paper) in PAPER_MRE {
        cfg.stages = stages(chip);
        eprintln!("[fig05] {chip} run...");
        let measured = train(&rt, &cfg).unwrap();
        let rep = check_alignment(chip, &reference.losses, &measured.losses);
        t.row(vec![
            chip.to_string(),
            format!("{:.3}%", rep.mre * 100.0),
            format!("{paper:.3}%"),
            if rep.aligned { "yes" } else { "NO" }.to_string(),
        ]);
        assert!(rep.aligned, "{chip} exceeded the {MRE_THRESHOLD} criterion: {}", rep.mre);
    }
    t.print();
    println!("OK: all chips satisfy the paper's MRE < 1.5% alignment criterion");
}
