//! Figure 7 — cross-chip P2P latency by communication strategy over the
//! message-size sweep, the collective-algorithm axis of the DiComm engine
//! on a two-node fabric, plus hot-path timing of the model itself.

use h2::comm::{allreduce_cost, p2p_latency, CommAlgo, CommMode, CommTopology, LinkTime};
use h2::util::bench::Bench;
use h2::util::table::{fmt_bytes, fmt_duration, Table};

fn main() {
    let sizes: Vec<usize> = (0..11).map(|i| 256usize << (2 * i)).collect(); // 256B..256MiB
    let mut t = Table::new(&["size", "TCP", "CPU-RDMA", "DDR", "TCP/DDR"])
        .with_title("Fig 7 — cross-chip P2P latency by strategy");
    let mut ratios = Vec::new();
    for &bytes in &sizes {
        let tcp = p2p_latency(CommMode::TcpCpu, bytes);
        let mid = p2p_latency(CommMode::RdmaCpu, bytes);
        let ddr = p2p_latency(CommMode::DeviceDirect, bytes);
        ratios.push(tcp / ddr);
        t.row(vec![
            fmt_bytes(bytes as f64),
            fmt_duration(tcp),
            fmt_duration(mid),
            fmt_duration(ddr),
            format!("{:.2}x", tcp / ddr),
        ]);
    }
    t.print();

    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    let min = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = ratios.iter().cloned().fold(0.0f64, f64::max);
    println!("\nDDR vs TCP: average {avg:.2}x, range {min:.2}x-{max:.2}x");
    println!("paper:      average 9.94x, range 1.79x-16.0x");
    assert!((avg - 9.94).abs() < 1.2, "average ratio {avg} drifted from paper");
    assert!((min - 1.79).abs() < 0.1 && (max - 16.0).abs() < 0.2);
    println!("OK: Fig 7 shape reproduced");

    // Collective-algorithm axis: one allreduce over 2 nodes x 8 ranks,
    // NVLink-class intra fabric (200 GB/s) vs a ~10 GB/s NIC flow —
    // closed-form engine costs per algorithm and the auto selection.
    let topo = CommTopology {
        n_ranks: 16,
        ranks_per_node: 8,
        intra: LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 },
        inter: LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 },
    };
    let mut t = Table::new(&["size", "ring", "tree", "rhd", "hierarchical", "auto picks"])
        .with_title("Comm-algo axis — allreduce on 2 nodes x 8 ranks (intra 20x NIC)");
    for &bytes in &sizes {
        let cost = |a| allreduce_cost(a, bytes, &topo).seconds;
        let pick = CommAlgo::Auto.resolve(bytes, &topo);
        t.row(vec![
            fmt_bytes(bytes as f64),
            fmt_duration(cost(CommAlgo::Ring)),
            fmt_duration(cost(CommAlgo::Tree)),
            fmt_duration(cost(CommAlgo::RecursiveHalvingDoubling)),
            fmt_duration(cost(CommAlgo::Hierarchical)),
            pick.token().to_string(),
        ]);
        // Shape checks: with the intra fabric 20x the NIC path, the
        // two-level schedule never loses to the flat ring, halving-
        // doubling never loses to the tree, and auto is the pointwise
        // minimum over the concrete algorithms.
        assert!(cost(CommAlgo::Hierarchical) <= cost(CommAlgo::Ring), "{bytes}");
        assert!(cost(CommAlgo::RecursiveHalvingDoubling) <= cost(CommAlgo::Tree), "{bytes}");
        let auto = allreduce_cost(CommAlgo::Auto, bytes, &topo).seconds;
        let best = CommAlgo::CONCRETE
            .iter()
            .map(|&a| cost(a))
            .fold(f64::INFINITY, f64::min);
        assert!(auto == best, "auto {auto} vs best {best} at {bytes}");
    }
    t.print();
    assert_eq!(CommAlgo::Auto.resolve(64 << 20, &topo), CommAlgo::Hierarchical,
               "large messages on this fabric must go hierarchical");
    println!("OK: comm-algo axis measured (hierarchical <= flat ring throughout)");

    // Hot-path timing of the latency model itself (used inside the
    // simulator's inner loop — must stay trivially cheap).
    let mut b = Bench::new("fig07 hot path").max_seconds(1.0);
    b.run("p2p_latency x 1k sizes", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += p2p_latency(CommMode::DeviceDirect, 64 << (i % 20));
        }
        std::hint::black_box(acc);
    });
    b.report();
}
