//! Figure 7 — cross-chip P2P latency by communication strategy over the
//! message-size sweep, plus hot-path timing of the model itself.

use h2::comm::{p2p_latency, CommMode};
use h2::util::bench::Bench;
use h2::util::table::{fmt_bytes, fmt_duration, Table};

fn main() {
    let sizes: Vec<usize> = (0..11).map(|i| 256usize << (2 * i)).collect(); // 256B..256MiB
    let mut t = Table::new(&["size", "TCP", "CPU-RDMA", "DDR", "TCP/DDR"])
        .with_title("Fig 7 — cross-chip P2P latency by strategy");
    let mut ratios = Vec::new();
    for &bytes in &sizes {
        let tcp = p2p_latency(CommMode::TcpCpu, bytes);
        let mid = p2p_latency(CommMode::RdmaCpu, bytes);
        let ddr = p2p_latency(CommMode::DeviceDirect, bytes);
        ratios.push(tcp / ddr);
        t.row(vec![
            fmt_bytes(bytes as f64),
            fmt_duration(tcp),
            fmt_duration(mid),
            fmt_duration(ddr),
            format!("{:.2}x", tcp / ddr),
        ]);
    }
    t.print();

    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    let min = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = ratios.iter().cloned().fold(0.0f64, f64::max);
    println!("\nDDR vs TCP: average {avg:.2}x, range {min:.2}x-{max:.2}x");
    println!("paper:      average 9.94x, range 1.79x-16.0x");
    assert!((avg - 9.94).abs() < 1.2, "average ratio {avg} drifted from paper");
    assert!((min - 1.79).abs() < 0.1 && (max - 16.0).abs() < 0.2);
    println!("OK: Fig 7 shape reproduced");

    // Hot-path timing of the latency model itself (used inside the
    // simulator's inner loop — must stay trivially cheap).
    let mut b = Bench::new("fig07 hot path").max_seconds(1.0);
    b.run("p2p_latency x 1k sizes", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += p2p_latency(CommMode::DeviceDirect, 64 << (i % 20));
        }
        std::hint::black_box(acc);
    });
    b.report();
}
