//! Figure 1 — chip capability space: compute / memory / communication per
//! chip, normalized to the A100, demonstrating that hyper-heterogeneous
//! chips admit no total order (the red-circle scenario of the paper).

use h2::hetero::{spec, ChipKind};
use h2::util::table::Table;

fn main() {
    let a100 = spec(ChipKind::A100);
    let mut t = Table::new(&["chip", "FP16 (xA100)", "memory (xA100)", "intra-BW (xA100)",
                             "chips/node"])
        .with_title("Fig 1 — capability space relative to A100");
    let mut rel: Vec<(ChipKind, f64, f64, f64)> = Vec::new();
    for kind in ChipKind::ALL {
        let s = spec(kind);
        let bw = s.intra_node.bandwidth_gbps(0, 1) / a100.intra_node.bandwidth_gbps(0, 1);
        let c = s.fp16_tflops / a100.fp16_tflops;
        let m = s.memory_gib / a100.memory_gib;
        rel.push((kind, c, m, bw));
        t.row(vec![
            kind.to_string(),
            format!("{c:.2}"),
            format!("{m:.2}"),
            format!("{bw:.2}"),
            s.chips_per_node.to_string(),
        ]);
    }
    t.print();

    // The hyper-heterogeneity property: chips mostly do NOT dominate each
    // other across all three axes.
    let mut dominated_pairs = 0;
    let mut total_pairs = 0;
    for i in 0..rel.len() {
        for j in 0..rel.len() {
            if i == j {
                continue;
            }
            total_pairs += 1;
            let (_, c1, m1, b1) = rel[i];
            let (_, c2, m2, b2) = rel[j];
            if c1 >= c2 && m1 >= m2 && b1 >= b2 {
                dominated_pairs += 1;
            }
        }
    }
    println!("\ncapability-incremental (dominating) pairs: {dominated_pairs}/{total_pairs}");
    println!("paper claim: hyper-heterogeneous chips follow no capability pattern");
    assert!(dominated_pairs < total_pairs / 2,
            "chip space looks capability-incremental, not hyper-heterogeneous");
    println!("OK: no total order across (compute, memory, bandwidth)");
}
